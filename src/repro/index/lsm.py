"""LSM-style live index: immutable base + small delta + write-ahead journal.

Mutation under traffic used to mean build-offline → snapshot → hot-swap.
This module exploits two algebraic facts that make a live write path
*exactly* correct for every engine:

* scatter-OR inserts are **idempotent and commutative**, so the union of
  two indexes built from read sets A and B equals one index built from
  A ∪ B, bit for bit;
* a match mask is a **conjunction over kmers of per-kmer memberships**,
  so OR-ing the per-kmer membership of two indexes *before* the integer
  coverage threshold answers exactly like the single merged index.

:class:`LiveIndex` holds an immutable **base** :class:`IndexState` plus a
small **delta** :class:`IndexState` that absorbs streaming inserts through
the existing fused ingest path (``InsertPlan.execute`` — same donated
scatter every engine uses). The delta shares the base's ``StateMeta`` by
default; for the bit-probe engines (flat BF, RAMBO) a second, smaller-``m``
:class:`IDLConfig` may size the delta independently (any ``m`` preserves
union semantics because the delta is probed with its own plan). Row-probe
engines (COBS, bit-sliced) share row geometry with the base — their row
count *is* the hash range.

Durability is a write-ahead **delta journal**: an append-only file of read
batches, each CRC-32 framed, written *before* the delta absorbs the batch.
A crash between compactions loses nothing — boot replays the journal into
a fresh delta (:meth:`LiveIndex.open`); a torn tail record (crash mid-
append, never acked) is detected by CRC/length and dropped.

Compaction folds delta into base **off the hot path**: when the two share
geometry it is ONE jitted elementwise OR of the packed uint32 words
(:func:`or_states`); a smaller-``m`` delta is folded by replaying the
journaled batches through the base's own insert plan. Either way the
merged state keeps the base ``StateMeta``, so publishing it through the
serving layer's swap protocol costs **zero recompiles** (state is a pytree
argument of every compiled step). :meth:`LiveIndex.publish` swaps base and
rebuilds the delta from any batches that arrived mid-compaction — the
two-phase dance ``plan_compaction → compact → publish`` lets the expensive
middle step run on a background thread while queries keep merging
base+delta. The journal is truncated only when the merged base reached
stable storage (``save_dir`` / ``durable=True``): until then it stays the
sole durable copy of the folded writes, so an in-memory-only compaction
never weakens the crash guarantee.
"""

from __future__ import annotations

import dataclasses
import os
import struct
import threading
import zlib
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idl as idl_mod
from repro.index import packed, query, store
from repro.index import state as state_mod

__all__ = [
    "DeltaJournal",
    "JournalError",
    "LiveIndex",
    "CompactionPlan",
    "empty_delta",
    "merge_kmer_hits",
    "or_states",
    "merged_msmt",
]


# ---------------------------------------------------------------------------
# The write-ahead delta journal.
# ---------------------------------------------------------------------------

class JournalError(RuntimeError):
    """A journal file failed structural validation (not a torn tail)."""


_MAGIC = b"IDLJ"
_VERSION = 1
_HEADER = struct.Struct("<4sI")           # magic, version
_REC = struct.Struct("<QIIi")             # seq, n_reads, read_len, n_fids


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One journaled write batch (reads + optional file ids)."""

    seq: int
    reads: np.ndarray                     # (B, read_len) uint8
    file_ids: Optional[np.ndarray]        # (B,) int32 or None


class DeltaJournal:
    """Append-only, CRC-framed write-ahead log of insert batches.

    Frame layout per record::

        <Q seq> <I n_reads> <I read_len> <i n_fids> <payload> <I crc32>

    ``n_fids`` is ``-1`` when the batch carried no file ids (single-set
    engines); the payload is the raw uint8 read bytes followed by int32
    file-id bytes; the CRC covers header + payload. Appends ``flush`` +
    ``fsync`` before returning, so an acked write survives a crash; a torn
    tail (crash mid-append) fails its CRC or length check on replay and is
    discarded — it was never acked. A bad record with valid records after
    it is NOT a torn tail: that is mid-file corruption of acked writes,
    and the constructor raises :class:`JournalError` rather than silently
    truncating them (see :meth:`_scan`).
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        tail = self._scan()
        self._fh = open(self.path, "ab")
        if self._fh.tell() > tail:        # physically drop a torn tail so
            self._fh.truncate(tail)       # new appends don't land after it
            self._fh.seek(tail)

    def _scan(self) -> int:
        """Validate the file; returns the byte offset after the last good
        record (creating the header if the file is new/empty).

        Only a TORN TAIL may be dropped: the final record failing its CRC
        or running past EOF is a crash mid-append (never acked). A bad
        record with a structurally valid, CRC-passing record anywhere
        after it is mid-file corruption of acked writes — that raises
        :class:`JournalError` instead of silently truncating them away.
        """
        if not os.path.exists(self.path) or os.path.getsize(self.path) == 0:
            with open(self.path, "wb") as fh:
                fh.write(_HEADER.pack(_MAGIC, _VERSION))
            return _HEADER.size
        with open(self.path, "rb") as fh:
            data = fh.read()
        if len(data) < _HEADER.size:
            raise JournalError(f"{self.path}: truncated journal header")
        magic, version = _HEADER.unpack(data[:_HEADER.size])
        if magic != _MAGIC:
            raise JournalError(
                f"{self.path}: not a delta journal (magic {magic!r})")
        if version > _VERSION:
            raise JournalError(
                f"{self.path}: journal version {version} is newer than "
                f"supported {_VERSION}")
        good = _HEADER.size
        while True:
            parsed = self._parse_record(data, good)
            if parsed is None:
                break
            good = parsed[1]
        if good < len(data):
            # a record failed at `good`. A torn tail is the ONLY thing we
            # may drop — probe every later offset for a valid record; a
            # hit means the middle of the file rotted under acked writes.
            probe = good + 1
            while probe + _REC.size + 4 <= len(data):
                if self._parse_record(data, probe) is not None:
                    raise JournalError(
                        f"{self.path}: corrupt record at byte {good} with "
                        f"valid records after it — mid-file corruption, "
                        f"not a torn tail; refusing to drop acked writes")
                probe += 1
        return good

    @staticmethod
    def _parse_record(data: bytes, off: int
                      ) -> Optional[Tuple[JournalRecord, int]]:
        """Try to parse one CRC-framed record at byte offset ``off``.

        Returns ``(record, next_offset)``, or None when no structurally
        valid record starts here (frame runs past EOF, or CRC mismatch —
        a header's declared gigabytes just fail the bounds check, nothing
        is ever allocated beyond what the buffer holds).
        """
        if off + _REC.size > len(data):
            return None
        head = data[off:off + _REC.size]
        seq, n_reads, read_len, n_fids = _REC.unpack(head)
        payload_len = n_reads * read_len + max(n_fids, 0) * 4
        end = off + _REC.size + payload_len + 4
        if end > len(data):
            return None
        payload = data[off + _REC.size:end - 4]
        if zlib.crc32(payload, zlib.crc32(head)) != \
                struct.unpack("<I", data[end - 4:end])[0]:
            return None
        reads = np.frombuffer(payload[:n_reads * read_len],
                              dtype=np.uint8).reshape(n_reads, read_len)
        fids = None
        if n_fids >= 0:
            fids = np.frombuffer(payload[n_reads * read_len:],
                                 dtype=np.int32).copy()
        return JournalRecord(seq=seq, reads=reads.copy(), file_ids=fids), end

    def append(self, seq: int, reads: np.ndarray,
               file_ids: Optional[np.ndarray]) -> None:
        reads = np.ascontiguousarray(reads, dtype=np.uint8)
        if reads.ndim == 1:
            reads = reads[None]
        fids = (None if file_ids is None
                else np.ascontiguousarray(file_ids, dtype=np.int32).reshape(-1))
        head = _REC.pack(int(seq), reads.shape[0], reads.shape[1],
                         -1 if fids is None else fids.shape[0])
        payload = reads.tobytes() + (b"" if fids is None else fids.tobytes())
        crc = zlib.crc32(payload, zlib.crc32(head))
        with self._lock:
            self._fh.write(head + payload + struct.pack("<I", crc))
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def records(self) -> List[JournalRecord]:
        """Every valid record in order (the boot-replay stream)."""
        out: List[JournalRecord] = []
        with self._lock:
            self._fh.flush()
        with open(self.path, "rb") as fh:
            data = fh.read()
        off = _HEADER.size
        while True:
            parsed = self._parse_record(data, off)
            if parsed is None:
                return out
            rec, off = parsed
            out.append(rec)

    def truncate_through(self, upto_seq: int) -> None:
        """Drop records with ``seq <= upto_seq`` (post-compaction), keeping
        later ones — rewritten atomically via a temp file + ``os.replace``."""
        keep = [r for r in self.records() if r.seq > upto_seq]
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as fh:
            fh.write(_HEADER.pack(_MAGIC, _VERSION))
            for r in keep:
                head = _REC.pack(r.seq, r.reads.shape[0], r.reads.shape[1],
                                 -1 if r.file_ids is None
                                 else r.file_ids.shape[0])
                payload = r.reads.tobytes() + (
                    b"" if r.file_ids is None else r.file_ids.tobytes())
                crc = zlib.crc32(payload, zlib.crc32(head))
                fh.write(head + payload + struct.pack("<I", crc))
            fh.flush()
            os.fsync(fh.fileno())
        with self._lock:
            self._fh.close()
            os.replace(tmp, self.path)
            self._fh = open(self.path, "ab")

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


# ---------------------------------------------------------------------------
# Delta construction + merge algebra.
# ---------------------------------------------------------------------------

def empty_delta(base: state_mod.IndexState,
                delta_cfg: Optional[idl_mod.IDLConfig] = None
                ) -> state_mod.IndexState:
    """A zeroed delta state for ``base``.

    Default: the base's exact ``StateMeta`` (same word shapes — the
    word-OR compaction fast path applies). ``delta_cfg`` sizes a smaller
    delta for the bit-probe engines (flat BF, RAMBO): any ``m`` keeps the
    two-probe merge exact because the delta is probed with its own plan.
    Row-probe engines (COBS, bit-sliced) must share base geometry — their
    row count is the hash range itself.
    """
    meta = base.meta
    if delta_cfg is None:
        return state_mod.IndexState(
            words=tuple(jnp.zeros_like(w) for w in base.words), meta=meta)
    if meta.engine not in ("bloom", "rambo"):
        raise ValueError(
            f"delta_cfg is only meaningful for bit-probe engines "
            f"(bloom, rambo); {meta.engine!r} deltas share the base row "
            f"geometry")
    cfg = meta.cfgs[0]
    if delta_cfg.k != cfg.k:
        raise ValueError(
            f"delta kmer size {delta_cfg.k} != base kmer size {cfg.k}")
    if delta_cfg.m % 32:
        raise ValueError(f"delta m={delta_cfg.m} must be a multiple of 32")
    new_meta = dataclasses.replace(meta, cfgs=(delta_cfg,))
    if meta.engine == "bloom":
        words = (jnp.zeros((delta_cfg.m // 32,), dtype=jnp.uint32),)
    else:                                  # rambo: (R*B, m/32) bucket stack
        words = (jnp.zeros(
            (meta.n_rep * meta.n_buckets, delta_cfg.m // 32),
            dtype=jnp.uint32),)
    return state_mod.IndexState(words=words, meta=new_meta)


def merge_kmer_hits(per_base: jax.Array, per_delta: jax.Array) -> jax.Array:
    """OR per-kmer membership of base and delta — the two-probe merge.

    Works on every engine's ``query_batch`` output: bool membership
    ((B, n_k) flat BF; (B, n_k, n_files) COBS/RAMBO) and packed uint32
    file masks ((B, n_k, W) bit-sliced). Because a match is a conjunction
    of per-kmer hits, OR-ing *before* the integer coverage threshold is
    exactly the answer a single merged index would give (equivalently:
    the AND of the two indexes' miss-masks).
    """
    return per_base | per_delta


@jax.jit
def or_states(base: state_mod.IndexState,
              delta: state_mod.IndexState) -> state_mod.IndexState:
    """Elementwise OR of two same-geometry states — the compaction fast
    path, one jitted op over the packed uint32 words (no donation: the
    inputs keep serving while the merge computes off the hot path)."""
    return jax.tree_util.tree_map(jnp.bitwise_or, base, delta)


def merged_msmt(base: state_mod.IndexState, delta: state_mod.IndexState,
                reads, theta: float = 1.0, *, backend: str = "jnp",
                **kw) -> jax.Array:
    """MSMT over the logical union of base and delta (two-probe merge).

    The reference the serving layer's batched steps are tested against:
    per-kmer outputs of both states OR-ed before the one integer coverage
    rule (``query.member_coverage`` / ``query.file_match_mask``).
    """
    per = merge_kmer_hits(
        state_mod.query(base, reads, backend=backend, **kw),
        state_mod.query(delta, reads, backend=backend, **kw))
    meta = base.meta
    if meta.engine == "bitsliced":
        mask = query.file_match_mask(per, theta)
        return packed.unpack_file_bits(mask, meta.n_files)
    return query.member_coverage(per, theta)


# ---------------------------------------------------------------------------
# The live index.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompactionPlan:
    """Snapshot of (base, delta, watermark) taken at plan time.

    The expensive merge runs off the hot path on these immutable values;
    writes that land after ``upto_seq`` stay in the live delta and are
    replayed into the fresh delta at publish time.
    """

    base: state_mod.IndexState
    delta: state_mod.IndexState
    upto_seq: int
    base_version: int
    tail: Tuple[JournalRecord, ...]       # records with seq <= upto_seq


class LiveIndex:
    """Immutable base + mutable delta + write-ahead journal.

    Thread model: ``insert`` / ``publish`` mutate under an internal lock
    and :meth:`states` hands out an atomic ``(base, delta, version, seq)``
    snapshot, but the *storage values* follow the repo's linear-use rule —
    an insert donates the previous delta buffer. All writes and query
    dispatches must therefore happen on one thread (the serving layer's
    flusher thread provides exactly that); a compactor thread only ever
    touches the immutable snapshots a :class:`CompactionPlan` carries.
    """

    def __init__(self, base, *,
                 delta_cfg: Optional[idl_mod.IDLConfig] = None,
                 journal: Optional[DeltaJournal] = None,
                 base_version: int = 0, start_seq: int = 0):
        self._lock = threading.RLock()
        self._base = state_mod.from_engine(base)
        self._delta_cfg = delta_cfg
        self._delta = empty_delta(self._base, delta_cfg)
        self._journal = journal
        self._base_version = int(base_version)
        # start_seq aligns a fresh replica's watermark with a fleet-level
        # journal whose earlier records were already compacted into `base`
        self._delta_seq = int(start_seq)
        self._compacted_seq = int(start_seq)  # writes <= this live in base
        self._tail: List[JournalRecord] = []
        if journal is not None:
            for rec in journal.records():         # boot replay (crash heal)
                self._apply(rec.reads, rec.file_ids, seq=rec.seq)

    # -- construction -------------------------------------------------------
    @classmethod
    def open(cls, snapshot_dir: str, *,
             journal_path: Optional[str] = None,
             delta_cfg: Optional[idl_mod.IDLConfig] = None,
             base_version: int = 0, **load_kw) -> "LiveIndex":
        """Boot from a versioned snapshot + journal: load the base through
        the store's CRC-verified path, then replay every journaled batch
        into a fresh delta — a crash between compactions loses nothing."""
        base = store.load(snapshot_dir, **load_kw)
        journal = (DeltaJournal(journal_path)
                   if journal_path is not None else None)
        return cls(base, delta_cfg=delta_cfg, journal=journal,
                   base_version=base_version)

    # -- views --------------------------------------------------------------
    @property
    def meta(self) -> state_mod.StateMeta:
        return self._base.meta

    @property
    def base(self) -> state_mod.IndexState:
        with self._lock:
            return self._base

    @property
    def delta(self) -> state_mod.IndexState:
        with self._lock:
            return self._delta

    @property
    def base_version(self) -> int:
        with self._lock:
            return self._base_version

    @property
    def delta_seq(self) -> int:
        """Journal sequence of the last absorbed batch (0 = delta empty)."""
        with self._lock:
            return self._delta_seq

    def delta_batches(self) -> int:
        """Write batches sitting in the delta — the compaction trigger."""
        with self._lock:
            return len(self._tail)

    def states(self) -> Tuple[state_mod.IndexState, state_mod.IndexState,
                              int, int]:
        """Atomic ``(base, delta, base_version, delta_seq)`` snapshot."""
        with self._lock:
            return self._base, self._delta, self._base_version, \
                self._delta_seq

    # -- the write path -----------------------------------------------------
    def _apply(self, reads, file_ids, *, seq: int, **kw) -> None:
        """Absorb one batch into the delta (journal already holds it)."""
        fids = file_ids
        if self._delta.meta.engine == "bloom":
            fids = None
        self._delta = state_mod.insert(
            self._delta, jnp.asarray(np.asarray(reads, dtype=np.uint8)),
            None if fids is None else np.asarray(fids), **kw)
        # max, not assignment: a lagging replica re-applying an explicit
        # fleet seq across a publish must never regress the watermark
        self._delta_seq = max(self._delta_seq, int(seq))
        self._tail.append(JournalRecord(
            seq=int(seq),
            reads=np.asarray(reads, dtype=np.uint8),
            file_ids=None if file_ids is None
            else np.asarray(file_ids, dtype=np.int32)))

    def insert(self, reads, file_ids=None, *, seq: Optional[int] = None,
               donate: bool = True, **kw) -> int:
        """Journal, then absorb one read batch into the delta.

        Write-ahead order: the journal append (flush + fsync) happens
        *before* the delta insert, so an acked sequence number is durable.
        ``seq`` assigns an EXPLICIT fleet-level sequence number (a router
        fanning one write-ahead-journaled stream to many replicas) instead
        of the local ``delta_seq + 1`` — so every replica's watermark is
        the fleet journal's, never a locally invented one. A ``seq`` the
        base already contains (``<=`` the last published compaction
        watermark — a lagging replica re-delivering across a publish) is
        an idempotent no-op. ``kw`` passes through to the shared ingest
        layer (``backend`` in {"jnp", "idl_insert", "sharded"}, ...).
        ``donate`` defaults ON, matching ``state.insert``: the single-
        writer discipline (all writes + query dispatch on one flusher
        thread) means nothing else holds the pre-insert delta, and
        :meth:`plan_compaction` copies the delta it freezes — so the
        scatter updates the delta in place instead of copying every word
        matrix per batch (that copy dominated insert-to-searchable
        latency). Pass ``donate=False`` only when an external reference
        to the current delta object must stay live across this call.
        Returns the batch's journal sequence number.
        """
        reads = np.asarray(reads, dtype=np.uint8)
        if reads.ndim == 1:
            reads = reads[None]
        with self._lock:
            seq = self._delta_seq + 1 if seq is None else int(seq)
            if seq <= self._compacted_seq:
                return seq                # already folded into the base
            if self._journal is not None:
                self._journal.append(seq, reads, file_ids)
            self._apply(reads, file_ids, seq=seq, donate=donate, **kw)
            return seq

    def replay(self, records) -> int:
        """Absorb already-journaled records at their ORIGINAL sequence
        numbers (no re-journaling) — how a router boots a fresh replica's
        delta into alignment with the fleet's write watermark. Returns the
        resulting ``delta_seq``.
        """
        with self._lock:
            for rec in records:
                self._apply(rec.reads, rec.file_ids, seq=rec.seq)
            return self._delta_seq

    # -- the merged read path ----------------------------------------------
    def query(self, reads, *, backend: str = "jnp", **kw) -> jax.Array:
        """Two-probe merged per-kmer membership (engine-shaped output)."""
        base, delta, _, _ = self.states()
        return merge_kmer_hits(
            state_mod.query(base, reads, backend=backend, **kw),
            state_mod.query(delta, reads, backend=backend, **kw))

    def msmt(self, reads, theta: float = 1.0, *, backend: str = "jnp",
             **kw) -> jax.Array:
        """MSMT over the logical union of base and delta."""
        base, delta, _, _ = self.states()
        return merged_msmt(base, delta, reads, theta, backend=backend, **kw)

    # -- compaction ---------------------------------------------------------
    def plan_compaction(self) -> CompactionPlan:
        """Freeze the merge inputs: everything up to the current seq.

        The delta words are COPIED under the lock: the write path donates
        the delta scatter (:meth:`insert`), so after the next insert the
        plan-time delta buffers are dead — the plan must own its bytes.
        One copy per compaction instead of one per insert is the whole
        point of the donation flip.
        """
        with self._lock:
            delta = state_mod.IndexState(
                words=tuple(jnp.array(w) for w in self._delta.words),
                meta=self._delta.meta)
            return CompactionPlan(
                base=self._base, delta=delta,
                upto_seq=self._delta_seq, base_version=self._base_version,
                tail=tuple(self._tail))

    @staticmethod
    def compact(plan: CompactionPlan) -> state_mod.IndexState:
        """Fold the plan's delta into its base (run off the hot path).

        Same geometry (default deltas): ONE jitted elementwise OR of the
        packed words. A smaller-``m`` delta (bit-probe engines) has
        different word shapes, so the journaled batches replay through the
        base's own insert plan instead — same union, by idempotence. The
        result always carries the *base* ``StateMeta``, which is what
        makes the publish a zero-recompile swap.
        """
        if plan.delta.meta == plan.base.meta:
            return or_states(plan.base, plan.delta)
        merged = plan.base
        for i, rec in enumerate(plan.tail):
            fids = rec.file_ids
            if merged.meta.engine == "bloom":
                fids = None
            # the first insert must not donate: plan.base is the state
            # still serving queries mid-compaction
            merged = state_mod.insert(
                merged, jnp.asarray(rec.reads), fids, donate=i > 0)
        return merged

    def publish(self, merged: state_mod.IndexState, upto_seq: int, *,
                durable: bool = False) -> int:
        """Swap the merged base in; rebuild the delta from late arrivals.

        Batches that landed after ``upto_seq`` (mid-compaction writes)
        replay into a fresh delta. Caller must hold the serving layer's
        hot-swap window (no query/write dispatch in flight) — the same
        discipline as ``GeneSearchService.swap_state``.

        Durability: the journal is the ONLY durable copy of the folded
        writes until the merged base reaches stable storage, so it is
        truncated only under ``durable=True`` — which the caller may pass
        only after saving ``merged`` through the snapshot store (the
        ``save_dir`` paths do exactly that). The default keeps every
        record: a crash after an in-memory-only compaction reboots from
        the previous snapshot + the full journal and loses nothing;
        :meth:`save_base` reclaims the journal at the next snapshot.
        Returns the new base version.
        """
        if merged.meta != self._base.meta:
            raise ValueError(
                "compacted state changed geometry: publish would recompile "
                "every serving step (meta must equal the base meta)")
        with self._lock:
            late = [r for r in self._tail if r.seq > upto_seq]
            self._base = merged
            self._base_version += 1
            self._delta = empty_delta(self._base, self._delta_cfg)
            self._tail = []
            seq = self._delta_seq
            self._delta_seq = int(upto_seq)
            self._compacted_seq = max(self._compacted_seq, int(upto_seq))
            for rec in late:
                self._apply(rec.reads, rec.file_ids, seq=rec.seq)
            self._delta_seq = max(self._delta_seq, int(seq))
            if durable and self._journal is not None:
                self._journal.truncate_through(upto_seq)
            return self._base_version

    def compact_now(self, *, save_dir: Optional[str] = None) -> int:
        """Inline plan → compact → publish (the synchronous convenience).

        ``save_dir`` writes the merged base through the versioned snapshot
        store BEFORE the publish, which is what licenses the journal
        truncation; without it the journal keeps every acked write (see
        :meth:`publish`). Returns the new base version.
        """
        plan = self.plan_compaction()
        merged = self.compact(plan)
        if save_dir is not None:
            store.save(merged, save_dir)
        return self.publish(merged, plan.upto_seq,
                            durable=save_dir is not None)

    def save_base(self, directory: str) -> str:
        """Write the current base through the versioned snapshot store,
        then reclaim journal records the saved base contains (they existed
        only to re-derive an UNSAVED base after a crash)."""
        with self._lock:
            base = self._base
            compacted = self._compacted_seq
        path = store.save(base, directory)
        if self._journal is not None:
            self._journal.truncate_through(compacted)
        return path

    def close(self) -> None:
        if self._journal is not None:
            self._journal.close()

"""Unified gene-sequence index subsystem.

One protocol (:class:`GeneIndex`), one hash-family registry
(:mod:`repro.index.registry`), one packed-word storage layer
(:mod:`repro.index.packed`), one shared query planner/executor
(:mod:`repro.index.query` — jnp / Pallas / sharded backends), four engines
(:mod:`repro.index.engines`). See docs/API.md for the full API and
migration notes from the deprecated ``core.bloom.BloomFilter`` /
``core.cobs.Cobs`` / ``core.rambo.Rambo`` classes.
"""

from repro.index import packed, query, registry
from repro.index.engines import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
)
from repro.index.protocol import GeneIndex
from repro.index.query import QueryPlan, plan_query
from repro.index.registry import HashScheme

__all__ = [
    "BitSlicedIndex",
    "CobsIndex",
    "GeneIndex",
    "HashScheme",
    "PackedBloomIndex",
    "QueryPlan",
    "RamboIndex",
    "packed",
    "plan_query",
    "query",
    "registry",
]

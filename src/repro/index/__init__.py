"""Unified gene-sequence index subsystem.

One protocol (:class:`GeneIndex`, v2: engines are views over a pytree
:class:`IndexState` — :mod:`repro.index.state`), one hash-family registry
(:mod:`repro.index.registry`), one packed-word storage layer
(:mod:`repro.index.packed`), one shared query planner/executor
(:mod:`repro.index.query` — jnp / Pallas / sharded backends), one shared
ingest planner/executor with a streaming archive builder
(:mod:`repro.index.ingest` — jnp / Pallas / sharded backends,
``build_archive``), one versioned snapshot store
(:mod:`repro.index.store` — ``save``/``load`` round-trip every engine
bit-exactly), four engines (:mod:`repro.index.engines`). See docs/API.md
for the full API and migration notes from the deprecated
``core.bloom.BloomFilter`` / ``core.cobs.Cobs`` / ``core.rambo.Rambo``
classes.
"""

from repro.index import ingest, lsm, packed, query, registry, shards, state, \
    store
from repro.index.engines import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
)
from repro.index.ingest import InsertPlan, build_archive, \
    build_sharded_archive, plan_insert
from repro.index.lsm import DeltaJournal, LiveIndex
from repro.index.protocol import GeneIndex
from repro.index.query import QueryPlan, plan_query
from repro.index.registry import HashScheme
from repro.index.shards import ShardSetError, ShardSetMeta, ShardSpec
from repro.index.state import IndexState, StaleIndexError, StateMeta
from repro.index.store import SnapshotError

__all__ = [
    "BitSlicedIndex",
    "CobsIndex",
    "DeltaJournal",
    "GeneIndex",
    "HashScheme",
    "IndexState",
    "InsertPlan",
    "LiveIndex",
    "PackedBloomIndex",
    "QueryPlan",
    "RamboIndex",
    "ShardSetError",
    "ShardSetMeta",
    "ShardSpec",
    "SnapshotError",
    "StaleIndexError",
    "StateMeta",
    "build_archive",
    "build_sharded_archive",
    "ingest",
    "lsm",
    "packed",
    "plan_insert",
    "plan_query",
    "query",
    "registry",
    "shards",
    "state",
    "store",
]

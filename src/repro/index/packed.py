"""Packed-uint32 word storage + the ONE dedup'd scatter-OR primitive.

Canonical storage for every engine behind the :class:`~repro.index.protocol.
GeneIndex` protocol: Bloom-filter bits live packed 32-per-``uint32`` word
(the layout the Pallas kernels and the serving index already use), not as
one byte per bit.

Since the ingest refactor all mutation flows through
:mod:`repro.index.ingest` (the shared ``InsertPlan`` layer), and the three
storage-specific scatter bodies this module used to carry (flat words,
bit-sliced, RAMBO rows) collapsed into one: :func:`scatter_or_matrix`, a
sort-deduplicated scatter-OR of single bits at ``(row, word_col, bit)``
targets of any packed ``(n_rows, W)`` matrix —

1. targets are ``lexsort``-ed and duplicates removed with a neighbour
   compare (no ``jnp.unique``, whose output shape is data-dependent and
   would break jit); duplicates are routed to an out-of-range row and
   dropped by the ``mode="drop"`` scatter;
2. the deduped bits are scatter-added into a zero delta (safe: each
   (row, word, bit) appears at most once, so add == or) and OR-ed into
   the destination.

The old per-layout helpers remain: ``scatter_or_bitsliced`` and
``scatter_or_rows`` as thin views of the one body, ``scatter_or`` as its
W == 1 single-sort-key specialization (the flat-BF fast path). The legacy
jit entry points (``insert_batch_words`` / ``insert_batch_bitsliced`` /
``insert_batch_rows``) finished their deprecation window and are now
call-time ``ImportError`` stubs pointing at ``ingest.InsertPlan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bloom as bloom_mod
from repro.core import idl as idl_mod
from repro.index import registry


def batch_locations(
    cfg: idl_mod.IDLConfig, reads: jax.Array, scheme: str, *, lane32: bool = False
) -> jax.Array:
    """(B, η, n_kmers) uint32 locations for a batch of equal-length reads."""
    fn = registry.locations32 if lane32 else registry.locations
    return jax.vmap(lambda codes: fn(cfg, codes, scheme))(reads)


# ---------------------------------------------------------------------------
# The one dedup'd scatter-OR body (pure jnp, jit/vmap safe).
# ---------------------------------------------------------------------------

def _mask_duplicates(sort_key_rows, primary: jax.Array, oob) -> jax.Array:
    """Return ``primary`` with duplicate entries replaced by ``oob``.

    ``sort_key_rows``: tuple-like (k, P) stack of already-sorted key rows;
    an entry is a duplicate iff every key row equals its left neighbour.
    """
    same = jnp.ones(primary.shape, dtype=bool)
    for row in sort_key_rows:
        same = same & jnp.concatenate(
            [jnp.zeros((1,), dtype=bool), row[1:] == row[:-1]]
        )
    return jnp.where(same, oob, primary)


def scatter_or_matrix(
    matrix: jax.Array,
    rows: jax.Array,
    word_cols: jax.Array,
    bits: jax.Array,
) -> jax.Array:
    """OR bit ``bits[i]`` of word ``(rows[i], word_cols[i])`` into ``matrix``.

    One lexsort + one scatter for the whole target stream, duplicate-safe;
    out-of-range targets (including deliberately masked ones routed to
    ``row == n_rows``) are dropped. This is the single scatter body behind
    every engine's insert path.
    """
    r = rows.reshape(-1).astype(jnp.int32)
    c = word_cols.reshape(-1).astype(jnp.int32)
    b = bits.reshape(-1).astype(jnp.uint32)
    order = jnp.lexsort((b, c, r))
    r, c, b = r[order], c[order], b[order]
    r = _mask_duplicates((r, c, b), r, matrix.shape[0])
    delta = jnp.zeros_like(matrix).at[r, c].add(
        jnp.uint32(1) << b, mode="drop")
    return matrix | delta


def scatter_or(words: jax.Array, locs: jax.Array) -> jax.Array:
    """OR the bits at flat bit-locations ``locs`` into packed ``words``.

    The W == 1 specialization of :func:`scatter_or_matrix`: flat bit
    locations are one key, so a single ``sort`` replaces the 3-key
    ``lexsort`` (the fast path every flat-BF insert takes). Out-of-range
    locations are dropped.
    """
    flat = jnp.sort(locs.reshape(-1).astype(jnp.uint32))
    word_idx = (flat >> jnp.uint32(5)).astype(jnp.int32)
    word_idx = _mask_duplicates((flat,), word_idx, words.shape[0])
    bit = jnp.uint32(1) << (flat & jnp.uint32(31))
    delta = jnp.zeros_like(words).at[word_idx].add(bit, mode="drop")
    return words | delta


def scatter_or_bitsliced(
    matrix: jax.Array, rows: jax.Array, file_ids: jax.Array
) -> jax.Array:
    """Set file bits at (row, file) pairs in a bit-sliced (m, F/32) matrix."""
    fids = file_ids.reshape(-1).astype(jnp.int32)
    return scatter_or_matrix(matrix, rows, fids >> 5, fids & 31)


def scatter_or_rows(
    filters: jax.Array, filter_rows: jax.Array, locs: jax.Array
) -> jax.Array:
    """Set bit ``locs[i]`` of packed filter row ``filter_rows[i]`` (RAMBO)."""
    flat = locs.reshape(-1).astype(jnp.int32)
    return scatter_or_matrix(filters, filter_rows, flat >> 5, flat & 31)


# ---------------------------------------------------------------------------
# Legacy batched entry points — removed; call-time ImportError stubs only.
# ---------------------------------------------------------------------------

def _removed(name: str, kind: str) -> "ImportError":
    return ImportError(
        f"packed.{name} was removed after its deprecation window; migrate: "
        f"ingest.plan_insert(cfg, scheme, reads.shape, dest.shape, "
        f"kind={kind!r}).execute(...) or the engine's insert_batch (see "
        "docs/API.md, 'Migration from the v1 serving surface')."
    )


def insert_batch_words(words, reads, *, cfg=None, scheme=None):
    """Removed legacy entry point — raises ImportError with the migration."""
    raise _removed("insert_batch_words", "bits")


def insert_batch_bitsliced(matrix, reads, cols, *, cfg=None, scheme=None,
                           lane32=False):
    """Removed legacy entry point — raises ImportError with the migration."""
    raise _removed("insert_batch_bitsliced", "cols")


def insert_batch_rows(filters, reads, filter_rows, *, cfg=None, scheme=None):
    """Removed legacy entry point — raises ImportError with the migration."""
    raise _removed("insert_batch_rows", "rows")


# ---------------------------------------------------------------------------
# Layout conversions (row-major stacks of packed filters).
# ---------------------------------------------------------------------------

def pack_rows(bits_u8: jax.Array) -> jax.Array:
    """(..., m) uint8 {0,1} -> (..., m/32) uint32 (rowwise pack_bits)."""
    m = bits_u8.shape[-1]
    if m % 32:
        raise ValueError(f"row length m={m} must be a multiple of 32")
    flat = bloom_mod.pack_bits(bits_u8.reshape(-1))
    return flat.reshape(bits_u8.shape[:-1] + (m // 32,))


def unpack_rows(words: jax.Array, m: int) -> jax.Array:
    """(..., m/32) uint32 -> (..., m) uint8 (rowwise unpack_bits)."""
    flat = bloom_mod.unpack_bits(words.reshape(-1))
    return flat.reshape(words.shape[:-1] + (m,))


def unpack_file_bits(masks: jax.Array, n_files: int) -> jax.Array:
    """(..., F/32) uint32 file masks -> (..., n_files) bool."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (masks[..., None] >> shifts) & jnp.uint32(1)
    return (bits.reshape(masks.shape[:-1] + (-1,))[..., :n_files]) == 1


def __getattr__(name: str):
    # coverage_need's single definition lives with the rest of the
    # query-side math (repro.index.query); re-exported here lazily so the
    # storage module keeps its historical surface without a duplicate body
    # or an import cycle.
    if name == "coverage_need":
        from repro.index import query

        return query.coverage_need
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

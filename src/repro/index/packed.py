"""Packed-uint32 word storage + batched, donated, dedup'd scatter inserts.

Canonical storage for every engine behind the :class:`~repro.index.protocol.
GeneIndex` protocol: Bloom-filter bits live packed 32-per-``uint32`` word
(the layout the Pallas kernels and the serving index already use), not as
one byte per bit. All mutation goes through the jit-compiled entry points
here, which share one structure:

1. locations for a whole ``(B, read_len)`` batch of reads are computed
   in-graph with ``vmap`` over the registry's rolling path — no per-read
   Python loop;
2. duplicate (target, bit) pairs are removed with a sort-based dedup
   (``lexsort`` + neighbour compare — no ``jnp.unique``, whose output shape
   is data-dependent and would break jit); duplicates are routed to an
   out-of-range row and dropped by the ``mode="drop"`` scatter;
3. the deduped bits are scatter-added into a zero delta (safe: each bit
   appears at most once, so add == or) and OR-ed into the donated
   destination buffer — one fused scatter per batch instead of a full
   ``m``-bit array copy per read.

The destination buffer is donated (``donate_argnums=0``): on accelerators
the update is in-place; CPU falls back to a copy with a one-time warning.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom as bloom_mod
from repro.core import idl as idl_mod
from repro.index import registry


def batch_locations(
    cfg: idl_mod.IDLConfig, reads: jax.Array, scheme: str, *, lane32: bool = False
) -> jax.Array:
    """(B, η, n_kmers) uint32 locations for a batch of equal-length reads."""
    fn = registry.locations32 if lane32 else registry.locations
    return jax.vmap(lambda codes: fn(cfg, codes, scheme))(reads)


# ---------------------------------------------------------------------------
# Dedup'd scatter-or primitives (pure jnp, jit/vmap safe).
# ---------------------------------------------------------------------------

def _mask_duplicates(sort_key_rows: jax.Array, primary: jax.Array, oob) -> jax.Array:
    """Return ``primary`` with duplicate entries replaced by ``oob``.

    ``sort_key_rows``: tuple-like (k, P) stack of already-sorted key rows;
    an entry is a duplicate iff every key row equals its left neighbour.
    """
    same = jnp.ones(primary.shape, dtype=bool)
    for row in sort_key_rows:
        same = same & jnp.concatenate(
            [jnp.zeros((1,), dtype=bool), row[1:] == row[:-1]]
        )
    return jnp.where(same, oob, primary)


def scatter_or(words: jax.Array, locs: jax.Array) -> jax.Array:
    """OR the bits at flat bit-locations ``locs`` into packed ``words``.

    One sort + one scatter for the whole location stream, duplicate-safe.
    """
    flat = jnp.sort(locs.reshape(-1).astype(jnp.uint32))
    word_idx = (flat >> jnp.uint32(5)).astype(jnp.int32)
    word_idx = _mask_duplicates((flat,), word_idx, words.shape[0])
    bit = jnp.uint32(1) << (flat & jnp.uint32(31))
    delta = jnp.zeros_like(words).at[word_idx].add(bit, mode="drop")
    return words | delta


def scatter_or_bitsliced(
    matrix: jax.Array, rows: jax.Array, file_ids: jax.Array
) -> jax.Array:
    """Set file bits at (row, file) pairs in a bit-sliced (m, F/32) matrix."""
    rows = rows.reshape(-1).astype(jnp.int32)
    fids = file_ids.reshape(-1).astype(jnp.int32)
    order = jnp.lexsort((fids, rows))
    r, f = rows[order], fids[order]
    r = _mask_duplicates((r, f), r, matrix.shape[0])
    bit = jnp.uint32(1) << (f & 31).astype(jnp.uint32)
    delta = jnp.zeros_like(matrix).at[r, f >> 5].add(bit, mode="drop")
    return matrix | delta


def scatter_or_rows(
    filters: jax.Array, filter_rows: jax.Array, locs: jax.Array
) -> jax.Array:
    """Set bit ``locs[i]`` of packed filter row ``filter_rows[i]`` (RAMBO)."""
    frows = filter_rows.reshape(-1).astype(jnp.int32)
    flat = locs.reshape(-1).astype(jnp.uint32)
    order = jnp.lexsort((flat, frows))
    fr, lc = frows[order], flat[order]
    fr = _mask_duplicates((fr, lc), fr, filters.shape[0])
    word_idx = (lc >> jnp.uint32(5)).astype(jnp.int32)
    bit = jnp.uint32(1) << (lc & jnp.uint32(31))
    delta = jnp.zeros_like(filters).at[fr, word_idx].add(bit, mode="drop")
    return filters | delta


# ---------------------------------------------------------------------------
# Jitted batched entry points (donated destination, static cfg + scheme).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("cfg", "scheme"))
def insert_batch_words(
    words: jax.Array, reads: jax.Array, *, cfg: idl_mod.IDLConfig, scheme: str
) -> jax.Array:
    """Insert a (B, read_len) batch into a flat packed BF — one jit call."""
    return scatter_or(words, batch_locations(cfg, reads, scheme))


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("cfg", "scheme", "lane32")
)
def insert_batch_bitsliced(
    matrix: jax.Array,
    reads: jax.Array,
    cols: jax.Array,
    *,
    cfg: idl_mod.IDLConfig,
    scheme: str,
    lane32: bool = False,
) -> jax.Array:
    """Insert a batch of reads into columns ``cols`` of a bit-sliced matrix."""
    locs = batch_locations(cfg, reads, scheme, lane32=lane32)
    b = reads.shape[0]
    rows = locs.reshape(b, -1)
    fids = jnp.broadcast_to(cols.reshape(b, 1), rows.shape)
    return scatter_or_bitsliced(matrix, rows, fids)


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("cfg", "scheme"))
def insert_batch_rows(
    filters: jax.Array,
    reads: jax.Array,
    filter_rows: jax.Array,
    *,
    cfg: idl_mod.IDLConfig,
    scheme: str,
) -> jax.Array:
    """Insert each read into ``R`` packed filter rows (RAMBO buckets).

    ``filter_rows``: (B, R) int32 — the stacked-filter rows read b lands in.
    """
    locs = batch_locations(cfg, reads, scheme)          # (B, η, n_k)
    b, r = filter_rows.shape
    per_read = locs.reshape(b, 1, -1)                   # (B, 1, η·n_k)
    lf = jnp.broadcast_to(per_read, (b, r, per_read.shape[-1]))
    ff = jnp.broadcast_to(filter_rows.reshape(b, r, 1), lf.shape)
    return scatter_or_rows(filters, ff, lf)


# ---------------------------------------------------------------------------
# Layout conversions (row-major stacks of packed filters).
# ---------------------------------------------------------------------------

def pack_rows(bits_u8: jax.Array) -> jax.Array:
    """(..., m) uint8 {0,1} -> (..., m/32) uint32 (rowwise pack_bits)."""
    m = bits_u8.shape[-1]
    if m % 32:
        raise ValueError(f"row length m={m} must be a multiple of 32")
    flat = bloom_mod.pack_bits(bits_u8.reshape(-1))
    return flat.reshape(bits_u8.shape[:-1] + (m // 32,))


def unpack_rows(words: jax.Array, m: int) -> jax.Array:
    """(..., m/32) uint32 -> (..., m) uint8 (rowwise unpack_bits)."""
    flat = bloom_mod.unpack_bits(words.reshape(-1))
    return flat.reshape(words.shape[:-1] + (m,))


def unpack_file_bits(masks: jax.Array, n_files: int) -> jax.Array:
    """(..., F/32) uint32 file masks -> (..., n_files) bool."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (masks[..., None] >> shifts) & jnp.uint32(1)
    return (bits.reshape(masks.shape[:-1] + (-1,))[..., :n_files]) == 1


def coverage_need(theta: float, n_kmers: int) -> int:
    """Integer hit threshold for kmer-coverage >= theta.

    Canonical implementation lives with the rest of the query-side math in
    :func:`repro.index.query.coverage_need`; re-exported here for storage
    users.
    """
    from repro.index import query

    return query.coverage_need(theta, n_kmers)

"""The ``GeneIndex`` protocol — one index API for every engine.

Every gene-sequence index in this repo (partitioned Bloom filter, COBS,
RAMBO, the bit-sliced serving index) speaks the same four-method protocol:

* ``build(cfg, ...)``                  — classmethod constructor;
* ``insert_batch(reads, file_ids)``    — index a ``(B, read_len)`` batch of
  base-code reads. Every engine routes through the shared ingest layer
  (:mod:`repro.index.ingest`): ``backend="jnp"`` is one jit-compiled,
  donated, dedup'd scatter (no per-read Python loop),
  ``backend="idl_insert"`` the host run-length planner + generalized
  Pallas ``insert_runs`` kernel (one launch per batch),
  ``backend="sharded"`` a collective-free ``shard_map`` over a 1-D device
  mesh. All three are bit-identical. ``file_ids`` is ignored by
  single-set engines;
* ``query_batch(reads, backend=...)``  — per-kmer membership for a batch.
  Every engine routes through the shared planner/executor layer
  (:mod:`repro.index.query`): ``backend="jnp"`` is the pure-XLA reference,
  ``backend="idl_probe"`` the host run-length planner + generalized Pallas
  ``probe_rows`` kernel, ``backend="sharded"`` a ``shard_map`` over a 1-D
  device mesh splitting the words/files axis. All three are bit-identical;
* ``msmt(reads, theta)``               — Multiple-Set Membership Testing
  (paper Definition 3): per-file kmer-coverage >= theta. ``theta=1.0``
  reproduces exact Membership Testing (Definition 2).

Engines are immutable: ``insert_batch`` returns a new index value whose
storage buffer was donated from the old one (linear-use style — keep only
the returned index). Hash families are resolved by name through
:mod:`repro.index.registry`; an engine never hard-codes a scheme.

**Protocol v2** makes the storage itself first-class: every engine is a
thin view over a :class:`repro.index.state.IndexState` — a registered
pytree whose leaves are the packed ``(n_rows, W)`` uint32 word matrices
and whose aux data is the static geometry. ``.state`` extracts it,
``.with_state(state)`` rebuilds a view, and the pure functions
``state.insert / state.query / state.msmt`` mirror the methods without an
object in sight — so a whole index can be jitted over, sharded,
snapshotted (:mod:`repro.index.store`) and served
(:mod:`repro.serving.service`) as a plain JAX value. Donation discipline
is enforced by the state layer: a consumed (donated-away) value raises
``state.StaleIndexError`` instead of crashing on a deleted buffer.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Protocol, runtime_checkable

import jax

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.index.state import IndexState


@runtime_checkable
class GeneIndex(Protocol):
    """Structural protocol shared by all index engines (v2)."""

    scheme: str

    @property
    def state(self) -> "IndexState":
        """The pytree-native storage behind this view."""
        ...

    def with_state(self, state: "IndexState") -> "GeneIndex":
        """Rebuild an engine view of the same kind over ``state``."""
        ...

    def insert_batch(
        self, reads: jax.Array, file_ids: Optional[jax.Array] = None
    ) -> "GeneIndex":
        """Index a batch of reads; returns the updated index."""
        ...

    def query_batch(self, reads: jax.Array, *, backend: str = "jnp") -> jax.Array:
        """Per-kmer membership for a batch of reads."""
        ...

    def msmt(self, reads: jax.Array, theta: float = 1.0) -> jax.Array:
        """Per-file match verdicts at kmer-coverage threshold ``theta``."""
        ...

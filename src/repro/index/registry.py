"""HashScheme registry — the single point of hash-family dispatch.

Every index engine (partitioned BF, COBS, RAMBO, the bit-sliced serving
index) used to carry its own ``if scheme == "idl": ...`` ladder. They now
all resolve a named :class:`HashScheme` here and call its location
functions. Adding a hash family is one :func:`register` call; every engine,
example and benchmark picks it up for free.

A scheme bundles up to three location paths:

* ``rolling``     — (cfg, codes) -> (η, n_kmers) uint locations for all
                    stride-1 kmers of a base-code sequence (the read path).
* ``kmer_batch``  — (cfg, packed_kmers) -> (η, n) locations for an arbitrary
                    batch of packed kmers (dedup pipelines). Optional.
* ``rolling32``   — 32-bit-lane variant of ``rolling`` (TPU serving path,
                    no int64). Optional.

Built-in schemes: ``idl`` (the paper's hash), ``rh`` (random-hash baseline),
``lsh`` (rehashed MinHash ablation, Table 4), ``idl-bbf`` (IDL × Blocked-BF
composition, §3.3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.core import idl as idl_mod

LocationFn = Callable[[idl_mod.IDLConfig, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class HashScheme:
    """A named hash family with its location paths."""

    name: str
    rolling: LocationFn
    kmer_batch: Optional[LocationFn] = None
    rolling32: Optional[LocationFn] = None
    doc: str = ""


_REGISTRY: dict[str, HashScheme] = {}


def register(scheme: HashScheme) -> HashScheme:
    """Register (or replace) a scheme under ``scheme.name``."""
    _REGISTRY[scheme.name] = scheme
    return scheme


def get(name: str) -> HashScheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown hash scheme {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def locations(cfg: idl_mod.IDLConfig, codes: jax.Array, scheme: str) -> jax.Array:
    """Rolling locations of ``scheme`` for all stride-1 kmers of ``codes``."""
    return get(scheme).rolling(cfg, codes)


def locations32(cfg: idl_mod.IDLConfig, codes: jax.Array, scheme: str) -> jax.Array:
    """32-bit-lane rolling locations (serving / TPU path)."""
    s = get(scheme)
    if s.rolling32 is None:
        raise ValueError(f"scheme {s.name!r} has no 32-bit lane path")
    return s.rolling32(cfg, codes)


def kmer_locations(cfg: idl_mod.IDLConfig, kmer_arr: jax.Array, scheme: str) -> jax.Array:
    """Locations for an arbitrary batch of packed kmers."""
    s = get(scheme)
    if s.kmer_batch is None:
        raise ValueError(f"kmer-batch API not defined for scheme {s.name!r}")
    return s.kmer_batch(cfg, kmer_arr)


# ---------------------------------------------------------------------------
# Built-in schemes.
# ---------------------------------------------------------------------------

register(HashScheme(
    name="idl",
    rolling=idl_mod.idl_locations_rolling,
    kmer_batch=idl_mod.idl_locations_kmer_batch,
    rolling32=idl_mod.idl_locations_rolling32,
    doc="IDentity with Locality: ψ(x) = ρ₁(MinHash(x)) + ρ₂(x) (Theorem 1).",
))

register(HashScheme(
    name="rh",
    rolling=idl_mod.rh_locations_rolling,
    kmer_batch=idl_mod.rh_locations,
    rolling32=idl_mod.rh_locations_rolling32,
    doc="Random-hash baseline (MurmurHash-style partitioned BF).",
))

register(HashScheme(
    name="lsh",
    rolling=idl_mod.lsh_locations_rolling,
    doc="Rehashed MinHash only (Table 4 ablation: locality, identity loss).",
))

register(HashScheme(
    name="idl-bbf",
    rolling=idl_mod.idl_bbf_locations_rolling,
    doc="IDL × Blocked-Bloom composition (§3.3): window + one cache line.",
))

"""Sharded archives: partition one :class:`IndexState` into N shard states.

The horizontal story (ROADMAP item 2). A match verdict is an integer
coverage threshold over per-kmer hit conjunctions, so shard-local partial
results merge **exactly** — sharding costs zero quality. Two partition
axes, chosen by how the engine probes its word matrix:

- ``axis="files"`` (row-probe engines: ``bitsliced``, ``cobs``) — each
  shard owns a contiguous file range and ALL bit rows for it. Bit-sliced
  shards slice word columns of the ``(m, ceil(F/32))`` matrix (each
  column is 32 files); COBS shards own whole size-groups. A file's
  verdict depends only on its own column, which lives wholly in one
  shard, so per-shard outputs merge by concatenation / OR over disjoint
  file sets — even AFTER thresholding.

- ``axis="words"`` (bit-probe engines: ``bloom``, ``rambo``) — each
  shard owns a slice of the packed-word rows (flat BF: rows of the
  ``(m/32,)`` vector; RAMBO: word-columns of the stored ``(R·B, m/32)``
  matrix, i.e. rows of the transposed probe matrix). Every probe lands
  in exactly ONE shard; a shard reduces its local probes to
  per-(kmer, slot) MISS counts over the η repetitions, and a kmer hits
  iff the total miss across shards is zero. :func:`merge_counts`
  combines the partial counts BEFORE the one coverage threshold
  (``query.coverage_need`` — the same rule ``query.file_match_mask`` /
  ``query.member_coverage`` apply), so the merge is exact by
  construction. This mirrors ``query._sharded_executor``'s psum, lifted
  from one mesh to N hosts.

Persistence: :func:`save_shard_set` writes each shard through the
ordinary snapshot store (``store.save``) into ``shard_NN/`` dirs plus a
CRC-checked top-level ``shardset.json`` manifest that pins every shard's
own manifest bytes; :func:`load_shard_set` / :func:`load_shard` reject
missing, foreign/rewritten, or mixed-geometry shards with
:class:`ShardSetError`\\ s naming the offending shard.

Build: :class:`ShardBuilder` is the bit-probe counterpart of a partition
slice — an engine-like facade ``ingest.build_archive`` can stream into,
computing full-geometry insert targets and keeping only the shard's word
range (scatter-OR commutes, so dropping foreign targets is exact; this
is ``ingest._sharded_inserter``'s body with a static shard id).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import zlib
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import packed, query
from repro.index import state as state_mod
from repro.index import store

AXES = ("files", "words")

SET_FORMAT = "idl-shard-set"
SET_VERSION = 1
SET_MANIFEST = "shardset.json"


class ShardSetError(store.SnapshotError):
    """A shard set (or one of its shards) is missing, foreign, or
    geometrically inconsistent with its manifest."""


# ---------------------------------------------------------------------------
# ShardSpec — the partition plan.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How one logical index is cut into ``n_shards`` pieces.

    ``bounds`` has ``n_shards + 1`` entries over the engine's partition
    units (bit-sliced: 32-file word columns; cobs: size-groups; bloom /
    rambo: packed words); shard ``s`` owns ``[bounds[s], bounds[s+1])``.
    ``meta`` is the FULL unsharded :class:`StateMeta` — the single source
    of truth every shard is validated against.
    """

    axis: str
    n_shards: int
    bounds: Tuple[int, ...]
    meta: state_mod.StateMeta

    def __post_init__(self):
        if self.axis not in AXES:
            raise ShardSetError(
                f"unknown shard axis {self.axis!r} (want one of {AXES})")
        if len(self.bounds) != self.n_shards + 1:
            raise ShardSetError(
                f"{self.n_shards} shards need {self.n_shards + 1} bounds, "
                f"got {len(self.bounds)}")

    @property
    def row_probe(self) -> bool:
        return self.axis == "files"

    def shard_units(self, shard_id: int) -> Tuple[int, int]:
        """``[lo, hi)`` partition-unit range owned by ``shard_id``."""
        if not 0 <= shard_id < self.n_shards:
            raise ShardSetError(
                f"shard id {shard_id} out of range (n_shards="
                f"{self.n_shards})")
        return self.bounds[shard_id], self.bounds[shard_id + 1]


@dataclasses.dataclass(frozen=True)
class ShardSetMeta:
    """Everything the top-level manifest pins: the spec, the set version
    serving stamps on results, the shard dir names, and each shard's own
    manifest CRC (how foreign/rewritten shards are detected)."""

    spec: ShardSpec
    set_version: int
    shard_dirs: Tuple[str, ...]
    manifest_crcs: Tuple[int, ...]


def _axis_units(meta: state_mod.StateMeta) -> Tuple[str, int, str]:
    """(axis, n_partition_units, unit name) for an engine's geometry."""
    if meta.engine == "bitsliced":
        return "files", -(-meta.n_files // 32), "32-file word columns"
    if meta.engine == "cobs":
        return "files", len(meta.cfgs), "size-groups"
    if meta.engine in ("bloom", "rambo"):
        return "words", meta.cfgs[0].m // 32, "packed words"
    raise ShardSetError(f"unknown engine {meta.engine!r}")


def plan_shards(meta: state_mod.StateMeta, n_shards: int) -> ShardSpec:
    """Cut an index's partition units into ``n_shards`` contiguous ranges."""
    axis, units, name = _axis_units(meta)
    if not 1 <= n_shards <= units:
        raise ShardSetError(
            f"cannot cut a {meta.engine!r} index into {n_shards} shards: "
            f"it has {units} {name} (want 1 <= n_shards <= {units})")
    bounds = tuple(i * units // n_shards for i in range(n_shards + 1))
    return ShardSpec(axis=axis, n_shards=n_shards, bounds=bounds, meta=meta)


def shard_files(spec: ShardSpec, shard_id: int) -> Tuple[int, ...]:
    """Global file ids owned by a row-probe shard (its file range)."""
    if not spec.row_probe:
        raise ShardSetError(
            f"{spec.meta.engine!r} shards partition the word axis — no "
            f"shard owns a file range")
    lo, hi = spec.shard_units(shard_id)
    if spec.meta.engine == "bitsliced":
        return tuple(range(32 * lo, min(32 * hi, spec.meta.n_files)))
    return tuple(f for g in spec.meta.group_file_ids[lo:hi] for f in g)


def _expect_shard(spec: ShardSpec, shard_id: int):
    """(expected shard StateMeta, expected per-array word shapes)."""
    meta = spec.meta
    lo, hi = spec.shard_units(shard_id)
    if meta.engine == "bitsliced":
        f_lo, f_hi = 32 * lo, min(32 * hi, meta.n_files)
        return (dataclasses.replace(meta, n_files=f_hi - f_lo),
                ((meta.cfgs[0].m, hi - lo),))
    if meta.engine == "cobs":
        gfi = meta.group_file_ids[lo:hi]
        return (dataclasses.replace(meta, cfgs=meta.cfgs[lo:hi],
                                    group_file_ids=gfi),
                tuple((c.m, -(-len(g) // 32))
                      for c, g in zip(meta.cfgs[lo:hi], gfi)))
    if meta.engine == "bloom":
        return meta, ((hi - lo,),)
    return meta, ((meta.n_rep * meta.n_buckets, hi - lo),)


def _validate_shard(spec: ShardSpec, shard_id: int,
                    shard: state_mod.IndexState, label: str) -> None:
    exp_meta, exp_shapes = _expect_shard(spec, shard_id)
    if shard.meta != exp_meta:
        raise ShardSetError(
            f"{label} has mixed geometry: its meta does not match the "
            f"shard set's ({shard.meta} != {exp_meta})")
    got = tuple(tuple(int(d) for d in w.shape) for w in shard.words)
    want = tuple(tuple(int(d) for d in s) for s in exp_shapes)
    if got != want:
        raise ShardSetError(
            f"{label} has mixed geometry: word shapes {got} != expected "
            f"{want}")


# ---------------------------------------------------------------------------
# Partition / join — proven bit-identical round trip.
# ---------------------------------------------------------------------------

def partition_state(index, n_shards: int):
    """Cut an engine/state into per-shard :class:`IndexState`\\ s.

    Returns ``(spec, [state, ...])``. Row-probe shards are themselves
    valid standalone engines over their file range (bit-sliced: a local
    ``n_files``; cobs: the owned groups with GLOBAL file ids and width —
    unowned files stay all-zero in its output). Bit-probe shards keep
    the FULL meta but hold only their word-range slice — they are probed
    through :func:`shard_query`, never as standalone engines. Slices
    are fresh arrays: the input state stays live.
    """
    full = state_mod.from_engine(index) if not isinstance(
        index, state_mod.IndexState) else index
    state_mod.ensure_live(full, *full.words, what="IndexState")
    spec = plan_shards(full.meta, n_shards)
    parts: List[state_mod.IndexState] = []
    for s in range(n_shards):
        lo, hi = spec.shard_units(s)
        exp_meta, _ = _expect_shard(spec, s)
        eng = full.meta.engine
        if eng == "cobs":
            words = tuple(full.words[lo:hi])
        elif eng == "bloom":
            words = (full.words[0][lo:hi],)
        else:  # bitsliced / rambo both slice word columns
            words = (full.words[0][:, lo:hi],)
        parts.append(state_mod.IndexState(words=words, meta=exp_meta))
    return spec, parts


def join_states(spec: ShardSpec,
                states: Sequence[state_mod.IndexState]) -> state_mod.IndexState:
    """Reassemble the unsharded :class:`IndexState` — bit-identical to
    the pre-partition input (asserted in tests/test_shards.py)."""
    if len(states) != spec.n_shards:
        raise ShardSetError(
            f"shard set wants {spec.n_shards} shards, got {len(states)}")
    for s, st in enumerate(states):
        _validate_shard(spec, s, st, f"shard {s}")
    eng = spec.meta.engine
    if eng == "cobs":
        words = tuple(w for st in states for w in st.words)
    elif eng == "bloom":
        words = (jnp.concatenate([st.words[0] for st in states], axis=0),)
    else:
        words = (jnp.concatenate([st.words[0] for st in states], axis=1),)
    return state_mod.IndexState(words=words, meta=spec.meta)


# ---------------------------------------------------------------------------
# Partial probe + exact merge.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def rambo_file_assignment(meta: state_mod.StateMeta) -> np.ndarray:
    """(R, N) int32 file->bucket map, reconstructed from meta alone (the
    assignment hash is deterministic, seed ``0xA3B0 + r``)."""
    from repro.index import engines

    return engines.rambo_assignment(meta.n_files, meta.n_buckets, meta.n_rep)


@functools.lru_cache(maxsize=128)
def partial_prober(cfg, scheme: str, lo: int, hi: int, transpose: bool):
    """jit-compiled bit-probe partial for one (geometry, word range).

    ``run(words, reads) -> (B, n_k, W') int32`` local MISS counts over
    the η repetitions (W' = 1 for flat BF, R·B for RAMBO) — the body of
    ``query._sharded_executor``'s bit-probe branch with a static shard
    range instead of ``axis_index``, summed across shards by
    :func:`merge_counts` instead of a psum. Probes outside ``[lo, hi)``
    contribute nothing; a kmer hits iff its TOTAL miss is zero.
    """
    span = hi - lo

    @jax.jit
    def run(words, reads):
        mat = words.T if transpose else jnp.reshape(words, (span, 1))
        locs = query.batch_locations(reads, cfg=cfg, scheme=scheme,
                                     lane32=False)   # (B, η, n_k)
        rows = (locs >> jnp.uint32(5)).astype(jnp.int32)
        local = (rows >= lo) & (rows < hi)
        got = mat[jnp.where(local, rows - lo, 0)]        # (B, η, n_k, W')
        bit = (got >> (locs & jnp.uint32(31))[..., None]) & jnp.uint32(1)
        miss = jnp.where(local[..., None], 1 - bit.astype(jnp.int32), 0)
        return jnp.sum(miss, axis=1)                     # (B, n_k, W')

    return run


def shard_query(spec: ShardSpec, shard_id: int,
                shard: state_mod.IndexState, reads, *,
                backend: str = "jnp"):
    """One shard's partial answer for a read batch.

    Row-probe shards run their engine's ordinary ``query_batch`` (their
    slice IS a complete index over their file range). Bit-probe shards
    return partial miss counts from :func:`partial_prober`. Feed the
    per-shard outputs, in shard order, to :func:`merge_counts`.
    """
    state_mod.ensure_live(shard, *shard.words, what="shard state")
    if spec.row_probe:
        return state_mod.to_engine(shard).query_batch(reads, backend=backend)
    lo, hi = spec.shard_units(shard_id)
    fn = partial_prober(spec.meta.cfgs[0], spec.meta.scheme, lo, hi,
                        spec.meta.engine == "rambo")
    reads = jnp.asarray(reads)
    if reads.ndim == 1:
        reads = reads[None]
    return fn(shard.words[0], reads)


def merge_counts(spec: ShardSpec, partials: Sequence):
    """Exactly reconstruct the unsharded engine's ``query_batch`` output
    from per-shard partials (shard order).

    The merge happens BEFORE the one coverage threshold
    (``query.file_match_mask`` / ``query.member_coverage``): bit-sliced
    per-kmer file masks concatenate on the word axis; cobs per-kmer
    grids OR over disjoint file sets; bit-probe miss counts sum, and a
    kmer hits iff the total is zero (every probe lands in exactly one
    shard). Bit-identical to the oracle by construction — asserted
    across engines × schemes × thetas in tests/test_shards.py.
    """
    if len(partials) != spec.n_shards:
        raise ShardSetError(
            f"merge_counts wants {spec.n_shards} partials, got "
            f"{len(partials)}")
    eng = spec.meta.engine
    if eng == "bitsliced":
        return jnp.concatenate(list(partials), axis=-1)
    if eng == "cobs":
        out = partials[0]
        for p in partials[1:]:
            out = jnp.logical_or(out, p)
        return out
    total = partials[0]
    for p in partials[1:]:
        total = total + p
    member = total == 0                                  # (B, n_k, W')
    if eng == "bloom":
        return member[..., 0]                            # (B, n_k) bool
    meta = spec.meta
    grid = member.reshape(member.shape[0], member.shape[1],
                          meta.n_rep, meta.n_buckets)
    idx = jnp.asarray(rambo_file_assignment(meta))[None, None]
    per_rep = jnp.take_along_axis(grid, idx, axis=3)     # (B, n_k, R, N)
    return jnp.all(per_rep, axis=2)                      # (B, n_k, N)


def sharded_msmt(spec: ShardSpec, states: Sequence[state_mod.IndexState],
                 reads, theta: float = 1.0, *, backend: str = "jnp"):
    """MSMT over the shard set — bit-identical to ``state.msmt`` on the
    joined index (the scatter-gather oracle, run in one process)."""
    per = merge_counts(spec, [
        shard_query(spec, s, st, reads, backend=backend)
        for s, st in enumerate(states)])
    if spec.meta.engine == "bitsliced":
        mask = query.file_match_mask(per, theta)
        return packed.unpack_file_bits(mask, spec.meta.n_files)
    return query.member_coverage(per, theta)


# ---------------------------------------------------------------------------
# Distributed build — the bit-probe shard's insert facade.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _shard_inserter(plan, lo: int, hi: int):
    """Donated scatter keeping only ``[lo, hi)`` — one compile per
    (plan, range); ``ingest._sharded_inserter``'s body with a static
    shard range. Foreign targets are remapped out of range and dropped
    by ``packed.scatter_or_matrix``; masked (minimizer) targets already
    carry the full-geometry OOB row, which is never local."""
    span = hi - lo
    split_rows = plan.kind == "bits"

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run(words, reads, aux):
        shape = words.shape
        row, wc, bit = plan.targets(reads, aux)
        if split_rows:
            local = (row >= lo) & (row < hi)
            row = jnp.where(local, row - lo, span)       # oob -> dropped
            mat = jnp.reshape(words, (span, 1))
        else:
            local = (wc >= lo) & (wc < hi)
            wc = jnp.where(local, wc - lo, span)
            mat = jnp.reshape(words, (shape[0], span))
        return packed.scatter_or_matrix(mat, row, wc, bit).reshape(shape)

    return run


class ShardBuilder:
    """Engine-like facade for streaming reads into ONE bit-probe shard.

    Quacks enough like an engine for ``ingest.build_archive`` (``cfg``
    for kmer size, ``insert_batch`` returning a new value): computes the
    full-geometry insert targets and scatters only those in this shard's
    word range. Windowed inserts hit every kmer and scatter-OR is
    idempotent and commutative, so N builders fed the same stream
    produce exactly the partition of the unsharded build. Linear-use
    like the engines: ``insert_batch`` donates the shard's buffer.
    """

    def __init__(self, spec: ShardSpec, shard_id: int,
                 shard: state_mod.IndexState):
        if spec.row_probe:
            raise ShardSetError(
                "ShardBuilder streams bit-probe shards; row-probe shards "
                "are standalone engines — build them with "
                "ingest.build_archive directly")
        self._spec = spec
        self._shard_id = shard_id
        self.state = shard

    @property
    def cfg(self):
        return self._spec.meta.cfgs[0]

    def insert_batch(self, reads, file_ids=None, *, backend: str = "jnp",
                     mesh=None, window_min=None, donate: bool = True,
                     **kw) -> "ShardBuilder":
        from repro.index import ingest as ingest_mod

        if backend != "jnp":
            raise ValueError(
                f"ShardBuilder scatters through the donated jnp path only "
                f"(got backend={backend!r})")
        del mesh, kw
        state_mod.ensure_live(self.state, *self.state.words,
                              what="shard state")
        meta = self._spec.meta
        cfg = meta.cfgs[0]
        reads = jnp.asarray(reads)
        if reads.ndim == 1:
            reads = reads[None]
        if meta.engine == "bloom":
            aux = None
            plan = ingest_mod.plan_insert(
                cfg, meta.scheme, tuple(reads.shape), (cfg.m // 32, 1),
                kind="bits", window_min=window_min)
        else:
            fids = np.atleast_1d(np.asarray(
                0 if file_ids is None else file_ids, dtype=np.int32))
            if fids.shape[0] == 1 and reads.shape[0] != 1:
                fids = np.broadcast_to(fids, (reads.shape[0],))
            asn = rambo_file_assignment(meta)
            offs = np.arange(meta.n_rep, dtype=np.int32) * meta.n_buckets
            aux = jnp.asarray(asn[:, fids].T + offs[None, :])   # (B, R)
            plan = ingest_mod.plan_insert(
                cfg, meta.scheme, tuple(reads.shape),
                (meta.n_rep * meta.n_buckets, cfg.m // 32),
                kind="rows", window_min=window_min)
        lo, hi = self._spec.shard_units(self._shard_id)
        words = self.state.words[0]
        if not donate:
            words = jnp.array(words, copy=True)
        else:
            state_mod.mark_consumed(self.state)
        new = _shard_inserter(plan, lo, hi)(words, reads, aux)
        return ShardBuilder(
            self._spec, self._shard_id,
            state_mod.IndexState(words=(new,), meta=self.state.meta))


# ---------------------------------------------------------------------------
# Persistence — per-shard snapshot dirs + a CRC-checked set manifest.
# ---------------------------------------------------------------------------

def _shard_dir(shard_id: int) -> str:
    return f"shard_{shard_id:02d}"


def _body_crc(body: dict) -> int:
    return zlib.crc32(json.dumps(body, sort_keys=True).encode("utf-8"))


def save_shard_set(spec: ShardSpec,
                   states: Sequence[state_mod.IndexState],
                   directory: str, *, version: int = 0) -> str:
    """Write a shard set: ``shard_NN/`` ordinary snapshots plus the
    CRC-checked top-level ``shardset.json`` pinning every shard's own
    manifest bytes. Geometry is validated BEFORE anything is written."""
    if len(states) != spec.n_shards:
        raise ShardSetError(
            f"shard set wants {spec.n_shards} shards, got {len(states)}")
    for s, st in enumerate(states):
        _validate_shard(spec, s, st, f"shard {s}")
    os.makedirs(directory, exist_ok=True)
    entries = []
    for s, st in enumerate(states):
        name = _shard_dir(s)
        store.save(st, os.path.join(directory, name))
        with open(os.path.join(directory, name, store.MANIFEST), "rb") as f:
            crc = zlib.crc32(f.read())
        entries.append({"dir": name, "manifest_crc32": crc})
    body = {
        "format": SET_FORMAT,
        "version": SET_VERSION,
        "set_version": int(version),
        "axis": spec.axis,
        "n_shards": spec.n_shards,
        "bounds": [int(b) for b in spec.bounds],
        "meta": store.meta_to_json(spec.meta),
        "shards": entries,
    }
    doc = {"crc32": _body_crc(body), "body": body}
    tmp = os.path.join(directory, SET_MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, os.path.join(directory, SET_MANIFEST))
    return directory


def is_shard_set(directory: str) -> bool:
    return os.path.exists(os.path.join(directory, SET_MANIFEST))


def read_set_meta(directory: str) -> ShardSetMeta:
    """Read + verify the top-level manifest — O(manifest), no array bytes.
    The scatter gateway boots its geometry from this alone."""
    path = os.path.join(directory, SET_MANIFEST)
    if not os.path.exists(path):
        raise ShardSetError(
            f"no {SET_MANIFEST} in {directory!r} — not a shard set")
    try:
        with open(path) as f:
            doc = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ShardSetError(
            f"corrupt {SET_MANIFEST} in {directory!r}: {e}") from e
    body = doc.get("body") if isinstance(doc, dict) else None
    if not isinstance(body, dict):
        raise ShardSetError(
            f"corrupt {SET_MANIFEST} in {directory!r}: no manifest body")
    if _body_crc(body) != doc.get("crc32"):
        raise ShardSetError(
            f"{SET_MANIFEST} in {directory!r} failed its checksum — the "
            f"shard-set manifest is truncated or rewritten")
    if body.get("format") != SET_FORMAT:
        raise ShardSetError(
            f"{directory!r} is not a shard set (format tag "
            f"{body.get('format')!r}, want {SET_FORMAT!r})")
    if body.get("version") != SET_VERSION:
        raise ShardSetError(
            f"shard-set format version {body.get('version')!r} in "
            f"{directory!r} is not supported (this build reads version "
            f"{SET_VERSION})")
    try:
        meta = store.meta_from_json(body["meta"])
        n = int(body["n_shards"])
        bounds = tuple(int(b) for b in body["bounds"])
        axis = body["axis"]
        shard_dirs = tuple(str(e["dir"]) for e in body["shards"])
        crcs = tuple(int(e["manifest_crc32"]) for e in body["shards"])
        set_version = int(body["set_version"])
    except (KeyError, TypeError, ValueError) as e:
        raise ShardSetError(
            f"corrupt {SET_MANIFEST} in {directory!r}: {e!r}") from e
    if len(shard_dirs) != n or len(crcs) != n:
        raise ShardSetError(
            f"shard-set manifest in {directory!r} lists "
            f"{len(shard_dirs)} shard dirs for n_shards={n}")
    for name in shard_dirs:
        if os.path.basename(name) != name or name in ("", ".", ".."):
            raise ShardSetError(
                f"shard dir {name!r} in {directory!r} is not a plain "
                f"directory name")
    spec = ShardSpec(axis=axis, n_shards=n, bounds=bounds, meta=meta)
    want = plan_shards(meta, n)
    if spec != want:
        raise ShardSetError(
            f"shard-set manifest in {directory!r} disagrees with the "
            f"partition plan for its own meta (axis/bounds drift)")
    return ShardSetMeta(spec=spec, set_version=set_version,
                        shard_dirs=shard_dirs, manifest_crcs=crcs)


def load_shard(directory: str, shard_id: int, *,
               set_meta: ShardSetMeta = None,
               **load_kw) -> Tuple[ShardSetMeta, state_mod.IndexState]:
    """Load ONE shard, validated against the set manifest: its dir must
    exist, its own manifest bytes must match the pinned CRC (foreign or
    rewritten shards are rejected by name), and its geometry must match
    the spec. ``load_kw`` passes through to ``store.load``."""
    sm = set_meta if set_meta is not None else read_set_meta(directory)
    if not 0 <= shard_id < sm.spec.n_shards:
        raise ShardSetError(
            f"shard id {shard_id} out of range (n_shards="
            f"{sm.spec.n_shards})")
    name = sm.shard_dirs[shard_id]
    sub = os.path.join(directory, name)
    manifest = os.path.join(sub, store.MANIFEST)
    if not os.path.exists(manifest):
        raise ShardSetError(
            f"shard {name!r} is missing from shard set {directory!r}")
    with open(manifest, "rb") as f:
        crc = zlib.crc32(f.read())
    if crc != sm.manifest_crcs[shard_id]:
        raise ShardSetError(
            f"shard {name!r} in {directory!r}: its {store.MANIFEST} does "
            f"not match the shard-set manifest (crc32 {crc} != "
            f"{sm.manifest_crcs[shard_id]}) — foreign or rewritten shard")
    try:
        st = store.load(sub, **load_kw)
    except ShardSetError:
        raise
    except store.SnapshotError as e:
        raise ShardSetError(f"shard {name!r} in {directory!r}: {e}") from e
    _validate_shard(sm.spec, shard_id, st, f"shard {name!r}")
    return sm, st


def load_shard_set(directory: str, **load_kw):
    """Load every shard. Returns ``(ShardSetMeta, [IndexState, ...])``."""
    sm = read_set_meta(directory)
    states = [load_shard(directory, s, set_meta=sm, **load_kw)[1]
              for s in range(sm.spec.n_shards)]
    return sm, states

"""The shared query-execution layer: one planned probe path for every engine.

The paper's whole argument is about the *query* side: IDL co-locates the
probes of successive kmers so membership tests hit one resident block
instead of scattering across the filter. Before this layer each engine
re-derived its own probe stream (``PackedBloomIndex`` reached the Pallas
planner, COBS / RAMBO / the bit-sliced serving index each had private
``jnp`` gather code). Now there is exactly one pipeline:

    kmer extraction -> hash-scheme codes -> row probes -> backend executor

unified by one observation: every engine's query is a **row gather over a
packed ``(n_rows, W)`` uint32 bit-matrix** followed by an AND over the η
hash repetitions —

======================  ==========================  =====================
Engine                  Probed matrix               Probe kind
======================  ==========================  =====================
``PackedBloomIndex``    ``(m/32, 1)`` word column   bit  (row = loc>>5)
``RamboIndex``          ``(m/32, R·B)`` transpose   bit  (row = loc>>5)
``CobsIndex`` group     ``(m_g, ⌈F_g/32⌉)``         row  (row = loc)
``BitSlicedIndex``      ``(m, ⌈F/32⌉)``             row  (row = loc)
======================  ==========================  =====================

A :class:`QueryPlan` holds everything static — config, scheme, read shape,
matrix geometry, the run-coalescing block height — and is built once per
``(cfg, scheme, read_shape, matrix_shape)`` through an LRU cache
(:func:`plan_query`). Executing a plan picks one of three backends:

* ``"jnp"``       — pure-XLA reference gather (always available);
* ``"idl_probe"`` — the host-side run-length planner + the generalized
  Pallas ``probe_rows`` kernel: probes are run-length-encoded by matrix
  row-block, each run DMAs ONE ``(rows_per_block, W)`` tile, and the whole
  ``(B, η, n_kmers)`` batch executes as a single kernel launch;
* ``"sharded"``   — ``shard_map`` over a 1-D device mesh. Bit probes split
  the words axis (each shard resolves its local probes and misses combine
  with a single ``lax.psum``); row probes split the file-words axis (the
  serving layout — gathers are device-local, outputs concatenate).

All backends are bit-identical; ``tests/test_index_parity.py`` holds the
parity matrix.

**Probe dedup** (``execute(..., dedup=True)``): membership of a kmer is a
pure function of ``(kmer, matrix)`` — the same kmer appearing twice in a
batch probes the same rows and ANDs to the same value, so factoring the
``(B, n_kmers)`` batch into its unique kmers, probing each once, and
inverse-permuting the per-kmer values back is an *exact* rewrite of the
naive path (scatter-OR/AND over duplicates is idempotent). The unique
kmers are probed in locality-sorted order — sorted by their repetition-0
hash location, so under IDL the dedup'd gather walks adjacent rows and is
also the DMA-minimal one. Unique counts are padded to the next power of
two, so the derived single-kmer plans (and their compiled executors) stay
bounded: one per ``(U_pad, k)`` shape, at most ``log2(B·n_kmers)`` of
them. ``tests/test_query_dedup.py`` holds the dedup == naive property
matrix across engines × schemes × backends.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import idl as idl_mod
from repro.index import packed
from repro.obs import metrics as obs_metrics

BACKENDS = ("jnp", "idl_probe", "sharded")
MESH_AXIS = "shards"


def record_locality(*, scheme: str, op: str, tile_bytes: int, n_runs: int,
                    n_probes: int, run_lengths) -> None:
    """Feed one executed probe/insert plan into the process registry —
    the paper's locality story as live counters: planned tile bytes (the
    quantity IDL minimizes), run/probe totals, and the per-run length
    histogram. Called once per executed batch on the planned backends
    (``idl_probe`` / ``idl_insert``), so an IDL stream and an RH stream
    over the same reads diverge visibly in
    ``locality.planned_tile_bytes``.

    The scalar counters are exact on EVERY batch (tile-byte ratios and
    run/probe totals are the paper's claim — they never sample); the
    run-length histogram, which is the only per-element cost here, is fed
    from every :data:`_HIST_SAMPLE`-th batch per (scheme, op) — a batch-
    granular sample that keeps the distribution honest (each sampled
    batch lands whole) at a quarter of the observe cost."""
    reg = obs_metrics.DEFAULT
    if not reg.enabled:
        return
    handles = _LOCALITY_HANDLES.get((scheme, op))
    if handles is None:
        # bind once per (scheme, op); the per-batch path below is then
        # pre-bound handle hits only
        labels = {"tier": "planner", "scheme": scheme, "op": op}
        handles = _LOCALITY_HANDLES[(scheme, op)] = (
            reg.counter("locality.planned_tile_bytes", **labels),
            reg.counter("locality.probe_runs", **labels),
            reg.counter("locality.probes", **labels),
            reg.counter("locality.batches", **labels),
            reg.histogram("locality.run_length", **labels),
        )
    c_bytes, c_runs, c_probes, c_batches, h_runs = handles
    c_bytes.inc(tile_bytes)
    c_runs.inc(n_runs)
    c_probes.inc(n_probes)
    c_batches.inc()
    if int(c_batches.value) % _HIST_SAMPLE == 1 or _HIST_SAMPLE == 1:
        h_runs.observe_array(run_lengths)


_LOCALITY_HANDLES: dict = {}

# Feed the run-length histogram from every Nth batch (1 = every batch).
# The first batch after a reset always lands (count % N == 1), so short
# tests and cold streams still populate the histogram.
_HIST_SAMPLE = 4

_FULL = jnp.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Location stream (shared by every backend).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "scheme", "lane32"))
def batch_locations(
    reads: jax.Array, *, cfg: idl_mod.IDLConfig, scheme: str, lane32: bool
) -> jax.Array:
    """(B, η, n_kmers) uint32 locations — jitted view of the one rolling
    location body the insert path (:mod:`repro.index.packed`) also uses."""
    return packed.batch_locations(cfg, reads, scheme, lane32=lane32)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def read_kmers(reads: np.ndarray, k: int) -> np.ndarray:
    """(B, read_len) uint8 reads -> (B·n_kmers, k) stride-1 kmer rows.

    A zero-copy sliding-window view reshaped (one small copy) — the host
    side of every dedup/cache path keys kmers by these byte rows.
    """
    arr = np.asarray(reads, dtype=np.uint8)
    if arr.ndim == 1:
        arr = arr[None]
    kms = np.lib.stride_tricks.sliding_window_view(arr, k, axis=1)
    return np.ascontiguousarray(kms.reshape(-1, k))


def factor_unique_kmers(
    reads, k: int
) -> tuple[np.ndarray, np.ndarray, tuple[int, int]]:
    """Factor a read batch into its distinct kmers.

    Returns ``(uniq, inverse, (b, n_kmers))``: ``uniq`` is ``(U, k)``
    uint8 (U = the distinct kmer count, lexicographic order) and
    ``inverse`` maps each of the ``b·n_kmers`` batch kmers to its row in
    ``uniq``. Membership of a kmer is a pure function of its bases, so
    probing ``uniq`` and gathering back through ``inverse`` is exact.
    Probe-side consumers pad ``uniq`` to a power of two themselves (so
    derived plans compile O(log) times, not per batch).
    """
    arr = np.asarray(reads, dtype=np.uint8)
    if arr.ndim == 1:
        arr = arr[None]
    b, read_len = arr.shape
    n_k = read_len - k + 1
    flat = read_kmers(arr, k)
    # unique rows via a void byte view: ONE memcmp sort, no per-column pass
    view = flat.view(np.dtype((np.void, k))).ravel()
    _, first, inverse = np.unique(view, return_index=True,
                                  return_inverse=True)
    return flat[first], inverse.reshape(-1), (b, n_k)


# ---------------------------------------------------------------------------
# The plan.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Static query recipe for one (cfg, scheme, read_shape, matrix) tuple.

    ``bit_probe=True``: locations are flat bit offsets — the probed row is
    ``loc >> 5`` and the answer is bit ``loc & 31`` of every word in that
    row. ``bit_probe=False``: locations are row indices and the answer is
    the whole W-word row (bit-sliced layouts).
    """

    cfg: idl_mod.IDLConfig
    scheme: str
    read_shape: tuple[int, int]       # (B, read_len)
    matrix_shape: tuple[int, int]     # (n_rows, W)
    bit_probe: bool
    lane32: bool
    rows_per_block: int               # run-coalescing DMA tile height
    probes_per_run: int

    @property
    def batch(self) -> int:
        return self.read_shape[0]

    @property
    def n_kmers(self) -> int:
        return self.read_shape[1] - self.cfg.k + 1

    @property
    def n_rows(self) -> int:
        return self.matrix_shape[0]

    @property
    def row_words(self) -> int:
        return self.matrix_shape[1]

    @property
    def block_bytes(self) -> int:
        """HBM bytes one run's DMA moves — the quantity IDL minimizes."""
        return self.rows_per_block * self.row_words * 4

    # -- probe streams ------------------------------------------------------
    def locations(self, reads: jax.Array) -> jax.Array:
        """(B, η, n_kmers) uint32 hash locations."""
        return batch_locations(
            reads, cfg=self.cfg, scheme=self.scheme, lane32=self.lane32
        )

    def row_indices(self, locs: jax.Array) -> jax.Array:
        """Matrix row probed by each location."""
        return (locs >> jnp.uint32(5)) if self.bit_probe else locs

    def plan_runs(self, reads: jax.Array):
        """Host-side run-length plan for the whole batch (one kernel launch).

        Returns ``(ProbePlan, locs)`` where locs is the (B, η, n_kmers)
        numpy location array the plan was built from.
        """
        from repro.kernels.idl_probe import ops as probe_ops

        locs = np.asarray(self.locations(reads))
        rows = (locs >> 5) if self.bit_probe else locs
        b, eta, n_k = locs.shape
        rplan = probe_ops.plan_probe_runs(
            rows.reshape(b * eta, n_k),
            block_bits=self.rows_per_block,
            probes_per_run=self.probes_per_run,
        )
        return rplan, locs

    def run_dma_bytes(self, rplan) -> int:
        """Total tile bytes the plan DMAs (n_runs × block_bytes)."""
        return rplan.n_runs * self.block_bytes

    # -- execution ----------------------------------------------------------
    def execute(
        self,
        matrix: jax.Array,
        reads: jax.Array,
        *,
        backend: str = "jnp",
        dedup: bool = False,
        interpret: Optional[bool] = None,
        use_ref: bool = False,
        mesh: Optional[Mesh] = None,
    ) -> jax.Array:
        """(B, n_kmers, W) uint32: AND over η of per-probe row values.

        ``bit_probe`` plans extract the probed bit first, so values are
        {0, 1} per word slot; row plans return full AND-ed word masks.
        ``matrix`` may be 1-D when ``W == 1`` (flat packed BF).

        ``dedup=True`` factors the batch into unique kmers, probes each
        once in locality-sorted order through the same backend, and
        inverse-permutes the per-kmer values back — bit-identical to the
        naive path (see module docstring) but with a probe stream sized
        by the batch's *distinct* kmers, the win for overlapping reads.
        """
        if backend == "kernel":   # pre-PR2 spelling of the planned backend
            backend = "idl_probe"
        if dedup:
            return self._execute_dedup(
                matrix, reads, backend=backend, interpret=interpret,
                use_ref=use_ref, mesh=mesh)
        if backend == "jnp":
            return _execute_jnp(matrix, reads, plan=self)
        if backend == "idl_probe":
            return self._execute_idl_probe(matrix, reads, interpret, use_ref)
        if backend == "sharded":
            return self._execute_sharded(matrix, reads, mesh)
        raise ValueError(
            f"unknown query backend {backend!r} (want one of {BACKENDS}; "
            f"'kernel' is accepted as an alias for 'idl_probe')"
        )

    def _execute_idl_probe(self, matrix, reads, interpret, use_ref):
        from repro.kernels.idl_probe import ops as probe_ops

        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        rplan, locs = self.plan_runs(reads)
        record_locality(
            scheme=self.scheme, op="query",
            tile_bytes=self.run_dma_bytes(rplan), n_runs=rplan.n_runs,
            n_probes=int(rplan.n_probes), run_lengths=rplan.run_lengths)
        gathered = probe_ops.gather_planned_rows(
            matrix, rplan, interpret=interpret, use_ref=use_ref,
        )                                           # (n_probes, W)
        b, eta, n_k = locs.shape
        gathered = gathered.reshape(b, eta, n_k, self.row_words)
        return _finish_probe(
            gathered, jnp.asarray(locs), bit_probe=self.bit_probe
        )

    def _execute_sharded(self, matrix, reads, mesh):
        if mesh is None:
            mesh = default_mesh()
        fn = _sharded_executor(self, mesh)
        return fn(matrix, reads)

    def _execute_dedup(self, matrix, reads, *, backend, interpret,
                       use_ref, mesh):
        """Unique-kmer probe path (host-factored, backend-shared).

        Each unique kmer is probed as a standalone length-k read through a
        derived ``(U_pad, k)`` plan — the rolling location of a kmer is a
        pure function of its own bases (per-kmer sliding-window MinHash),
        so the standalone probe is bit-identical to the in-read one.
        """
        k = self.cfg.k
        uniq, inverse, (b, n_k) = factor_unique_kmers(reads, k)
        u_pad = _next_pow2(len(uniq))
        if u_pad > len(uniq):   # pad rows repeat the last unique kmer, so
            uniq = np.concatenate(  # plans compile O(log) times, not per batch
                [uniq, np.broadcast_to(uniq[-1], (u_pad - len(uniq), k))])
        kplan = plan_query(
            self.cfg, self.scheme, (len(uniq), k), self.matrix_shape,
            bit_probe=self.bit_probe, lane32=self.lane32,
            rows_per_block=self.rows_per_block,
            probes_per_run=self.probes_per_run)
        ukmers = jnp.asarray(uniq)
        # locality sort: order unique kmers by their repetition-0 hash
        # location, so under IDL the dedup'd probe stream walks adjacent
        # matrix rows (the DMA-minimal order). One extra hash pass over
        # the unique set — cheap next to the gather it orders.
        locs0 = np.asarray(kplan.locations(ukmers))[:, 0, 0]
        order = np.argsort(locs0, kind="stable")
        rank = np.empty_like(order)
        rank[order] = np.arange(len(order))
        vals = kplan.execute(
            matrix, ukmers[jnp.asarray(order)], backend=backend,
            interpret=interpret, use_ref=use_ref, mesh=mesh)  # (U_pad, 1, W)
        per = jnp.take(vals[:, 0], jnp.asarray(rank[inverse]), axis=0)
        return per.reshape(b, n_k, vals.shape[-1])


def _pow2_block(n_rows: int, target: int) -> int:
    """Largest power of two <= target that divides n_rows (floor 1)."""
    blk = 1 << max(int(target).bit_length() - 1, 0)
    while blk > 1 and n_rows % blk:
        blk //= 2
    return max(blk, 1)


# Bounded: a long-lived server planning many geometries (every (bucket,
# unique-count) pair of the dedup path derives a plan) must not grow this
# without bound. Eviction is cheap by design — plans are frozen VALUE
# objects, and every jitted executor keys on the plan's hash/eq, so a
# rebuilt equal plan hits the same compiled executable (compile-once under
# eviction pressure is asserted in tests/test_query_dedup.py).
PLAN_CACHE_SIZE = 512


@functools.lru_cache(maxsize=PLAN_CACHE_SIZE)
def plan_query(
    cfg: idl_mod.IDLConfig,
    scheme: str,
    read_shape: tuple[int, int],
    matrix_shape: tuple[int, int],
    *,
    bit_probe: bool,
    lane32: bool = False,
    rows_per_block: Optional[int] = None,
    probes_per_run: Optional[int] = None,
) -> QueryPlan:
    """Build (or fetch) the cached plan for one query geometry.

    ``rows_per_block`` defaults to the IDL locality window ``cfg.L``
    translated to matrix rows (``L/32`` packed words for bit probes, ``L``
    rows for row probes), clamped to a VMEM-friendly power of two that
    divides ``n_rows``. ``probes_per_run`` defaults to the TPU lane width
    (128); on a CPU host 32 — narrower runs waste fewer pad lanes where
    there is no vector unit to fill.
    """
    n_rows, row_words = matrix_shape
    if probes_per_run is None:
        probes_per_run = 32 if jax.default_backend() == "cpu" else 128
    if rows_per_block is None:
        if bit_probe:
            target = max(cfg.L // 32, 1)
        else:
            # keep one DMA tile's unpacked bit image ~<= 2 MB of f32
            target = max(8, min(cfg.L, (1 << 21) // max(row_words * 128, 1)))
        rows_per_block = _pow2_block(n_rows, target)
    if n_rows % rows_per_block:
        raise ValueError(
            f"rows_per_block={rows_per_block} must divide n_rows={n_rows}"
        )
    return QueryPlan(
        cfg=cfg, scheme=scheme,
        read_shape=tuple(read_shape), matrix_shape=tuple(matrix_shape),
        bit_probe=bit_probe, lane32=lane32,
        rows_per_block=rows_per_block, probes_per_run=probes_per_run,
    )


class PlanCacheInfo(NamedTuple):
    """``lru_cache`` stats plus the eviction count a bounded cache needs.

    ``evictions`` is exact: every miss inserts one entry and ``currsize``
    counts the retained ones, so ``misses - currsize`` is how many were
    pushed out (both reset together on ``clear_plan_cache``).
    """

    hits: int
    misses: int
    maxsize: Optional[int]
    currsize: int
    evictions: int


def _with_evictions(info) -> PlanCacheInfo:
    return PlanCacheInfo(
        hits=info.hits, misses=info.misses, maxsize=info.maxsize,
        currsize=info.currsize, evictions=info.misses - info.currsize)


def plan_cache_info() -> PlanCacheInfo:
    """Stats of the (bounded) plan cache — hits prove plans are built
    once, ``evictions`` proves the bound is real under pressure."""
    return _with_evictions(plan_query.cache_info())


def clear_plan_cache() -> None:
    plan_query.cache_clear()


# ---------------------------------------------------------------------------
# Backend bodies.
# ---------------------------------------------------------------------------

def _finish_probe(rows: jax.Array, locs: jax.Array, *, bit_probe: bool):
    """(B, η, n_k, W) gathered rows -> (B, n_k, W) AND-over-η values."""
    if bit_probe:
        bit = (locs & jnp.uint32(31))[..., None]
        vals = (rows >> bit) & jnp.uint32(1)
    else:
        vals = rows
    return jax.lax.reduce(vals, _FULL, jax.lax.bitwise_and, dimensions=(1,))


@functools.partial(jax.jit, static_argnames=("plan",))
def _execute_jnp(matrix: jax.Array, reads: jax.Array, *, plan: QueryPlan):
    matrix = jnp.reshape(matrix, plan.matrix_shape)
    locs = plan.locations(reads)
    rows = matrix[plan.row_indices(locs).astype(jnp.int32)]
    return _finish_probe(rows, locs, bit_probe=plan.bit_probe)


@functools.lru_cache(maxsize=None)
def default_mesh() -> Mesh:
    """1-D mesh over every visible device (the scale-out words/files axis)."""
    return Mesh(np.asarray(jax.devices()), (MESH_AXIS,))


# Bounded like the plan cache — but note the asymmetry: evicting an
# EXECUTOR drops its compiled closure, so a cold re-entry recompiles
# (jit caches key on the closure object, not the plan value). 128 keeps
# every realistic working set hot; the bound only guards runaway variety.
@functools.lru_cache(maxsize=128)
def _sharded_executor(plan: QueryPlan, mesh: Mesh):
    """jit-compiled shard_map executor for one (plan, mesh) pair."""
    n_shards = int(np.prod(mesh.devices.shape))
    n_rows, w = plan.matrix_shape

    if plan.bit_probe:
        # Split the words (row) axis: every probe is local to exactly one
        # shard. Each shard reduces its local probes to per-(kmer, slot)
        # miss counts over η; ONE psum combines shards; a hit is zero
        # misses anywhere.
        rows_per_shard = -(-n_rows // n_shards)

        def body(mat, reads):
            locs = plan.locations(reads)
            rows = plan.row_indices(locs).astype(jnp.int32)
            lo = jax.lax.axis_index(MESH_AXIS).astype(jnp.int32) * rows_per_shard
            local = (rows >= lo) & (rows < lo + rows_per_shard)
            got = mat[jnp.where(local, rows - lo, 0)]       # (B, η, n_k, W)
            bit = (got >> (locs & jnp.uint32(31))[..., None]) & jnp.uint32(1)
            miss = jnp.where(local[..., None], 1 - bit.astype(jnp.int32), 0)
            miss = jnp.sum(miss, axis=1)                    # (B, n_k, W)
            return jax.lax.psum(miss, MESH_AXIS)

        pad = rows_per_shard * n_shards - n_rows

        def run(matrix, reads):
            matrix = jnp.reshape(matrix, plan.matrix_shape)
            if pad:
                matrix = jnp.pad(matrix, ((0, pad), (0, 0)))
            miss = shard_map(
                body, mesh=mesh,
                in_specs=(P(MESH_AXIS, None), P()), out_specs=P(),
            )(matrix, reads)
            return (miss == 0).astype(jnp.uint32)

        return jax.jit(run)

    # Row probe: split the file-words axis (the serving layout) — every
    # shard holds all rows for its file slice, gathers are device-local and
    # the only collective is the output concatenation.
    words_per_shard = -(-w // n_shards)

    def body(mat, reads):
        locs = plan.locations(reads)
        rows = mat[locs.astype(jnp.int32)]                  # (B, η, n_k, W/s)
        return jax.lax.reduce(rows, _FULL, jax.lax.bitwise_and, dimensions=(1,))

    pad = words_per_shard * n_shards - w

    def run(matrix, reads):
        matrix = jnp.reshape(matrix, plan.matrix_shape)
        if pad:
            matrix = jnp.pad(matrix, ((0, 0), (0, pad)))
        out = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, MESH_AXIS), P()),
            out_specs=P(None, None, MESH_AXIS),
        )(matrix, reads)
        return out[..., :w]

    return jax.jit(run)


# ---------------------------------------------------------------------------
# Shared coverage reductions (MSMT postludes).
# ---------------------------------------------------------------------------

def coverage_need(theta: float, n_kmers: int) -> int:
    """Integer hit threshold for kmer-coverage >= theta (exact at 1.0).

    The ONE definition of the theta rule — engines, ``serve_step`` and the
    v2 serving layer all resolve their thresholds here (host-side, exact
    float64; an in-graph f32 ``theta * n`` can flip boundary thetas).
    """
    return int(np.ceil(theta * n_kmers - 1e-9))


def _need_threshold(theta, n_kmers: int, need, lead_ndim: int):
    """Resolve ``need`` to something comparable against (B, ...) hit counts.

    ``need=None``: the scalar host-side :func:`coverage_need` of the full
    kmer axis. Otherwise a (B,) int array of per-row thresholds (the padded
    serving path: each row's threshold comes from its TRUE kmer count),
    reshaped to broadcast over ``lead_ndim`` trailing hit dimensions.
    """
    if need is None:
        return coverage_need(theta, n_kmers)
    need = jnp.asarray(need, dtype=jnp.int32)
    return need.reshape(need.shape + (1,) * lead_ndim)


def member_coverage(member: jax.Array, theta: float = 1.0, *,
                    valid: Optional[jax.Array] = None,
                    need=None) -> jax.Array:
    """(B, n_kmers[, ...]) bool kmer hits -> (B[, ...]) bool coverage >= θ.

    ``valid``: optional (B, n_kmers) bool marking REAL kmers — padding
    slots of a shape-bucketed batch are excluded from the hit count.
    ``need``: optional (B,) int32 per-row hit thresholds overriding theta
    (each padded row keeps the threshold of its true, unpadded length).
    """
    hits = member.astype(jnp.int32)
    if valid is not None:
        v = valid.astype(jnp.int32)
        hits = hits * v.reshape(v.shape + (1,) * (member.ndim - 2))
    hits = jnp.sum(hits, axis=1)
    return hits >= _need_threshold(theta, member.shape[1], need, hits.ndim - 1)


def file_match_mask(per_kmer: jax.Array, theta: float = 1.0, *,
                    valid: Optional[jax.Array] = None,
                    need=None) -> jax.Array:
    """(B, n_kmers, W) uint32 kmer file-masks -> (B, W) uint32 match mask.

    theta=1: pure AND over kmers. theta<1 (or per-row ``need``): per-file
    popcount against the exact integer threshold (a float mean of n ones
    != 1.0 in f32 for many n, which would flip boundary thetas).

    ``valid`` (B, n_kmers) bool marks real kmers of a shape-bucketed padded
    batch: pad kmers are neutralized (all-ones under AND, zero hits under
    popcount). ``need`` (B,) int32 gives per-row thresholds for rows whose
    true kmer counts differ (see :func:`coverage_need`).
    """
    if theta >= 1.0 and need is None:
        if valid is not None:
            per_kmer = jnp.where(valid[..., None], per_kmer, _FULL)
        return jax.lax.reduce(per_kmer, _FULL, jax.lax.bitwise_and,
                              dimensions=(1,))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (per_kmer[..., None] >> shifts) & jnp.uint32(1)
    if valid is not None:
        bits = bits * valid[..., None, None].astype(jnp.uint32)
    hits = jnp.sum(bits.astype(jnp.int32), axis=1)          # (B, W, 32)
    thresh = _need_threshold(theta, per_kmer.shape[1], need, hits.ndim - 1)
    match = (hits >= thresh).astype(jnp.uint32)
    return jnp.sum(match << shifts, axis=-1, dtype=jnp.uint32)

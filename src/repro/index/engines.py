"""The four index engines behind the :class:`GeneIndex` protocol.

=====================  =====================================================
Engine                 Storage (canonical packed-uint32 words)
=====================  =====================================================
PackedBloomIndex       flat partitioned BF: ``(m/32,)``
CobsIndex              size-grouped bit-sliced matrices: ``(m_g, ⌈F_g/32⌉)``
RamboIndex             stacked bucket BFs: ``(R·B, m_b/32)``
BitSlicedIndex         single bit-sliced matrix: ``(m, ⌈F/32⌉)`` (serving)
=====================  =====================================================

All engines resolve their hash family by name through
:mod:`repro.index.registry`. Engines are immutable dataclasses and thin
*views* over a :class:`repro.index.state.IndexState` pytree (``.state`` /
``.with_state()`` — protocol v2); ``insert_batch`` returns a new value and
donates the old buffer (linear use). The donated input is marked consumed:
using it again raises :class:`repro.index.state.StaleIndexError` with a
clear message instead of a backend-dependent deleted-buffer crash; pass
``donate=False`` to keep the input alive at the cost of one copy.

Both data paths route through shared planner/executor layers that treat
every engine's storage as a packed ``(n_rows, W)`` bit-matrix:

* queries through :mod:`repro.index.query` — backends ``"jnp"`` (pure-XLA
  gather), ``"idl_probe"`` (host run-length planner + the generalized
  Pallas ``probe_rows`` kernel), ``"sharded"`` (``shard_map`` over a 1-D
  device mesh);
* inserts through :mod:`repro.index.ingest` — backends ``"jnp"`` (one
  donated sort-dedup scatter), ``"idl_insert"`` (host run planner + the
  Pallas ``insert_runs`` kernel, one launch per batch), ``"sharded"``
  (device-local scatters, no collectives).

All backends of both paths are bit-identical
(``tests/test_index_parity.py``, ``tests/test_ingest.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, idl as idl_mod
from repro.index import ingest, packed, query
from repro.index import state as state_mod


def _as_batch(reads: jax.Array) -> jax.Array:
    reads = jnp.asarray(reads)
    return reads[None, :] if reads.ndim == 1 else reads


class _StateView:
    """Protocol-v2 mixin: every engine is a thin view over an IndexState."""

    @property
    def state(self) -> state_mod.IndexState:
        """The pytree-native storage behind this view."""
        return state_mod.from_engine(self)

    def with_state(self, state: state_mod.IndexState):
        """Rebuild an engine view over ``state`` (same kind required)."""
        kind = state_mod.from_engine(self).meta.engine
        if state.meta.engine != kind:
            raise ValueError(
                f"with_state: state is for engine {state.meta.engine!r}, "
                f"this view is {kind!r}")
        return state_mod.to_engine(state)


def _as_file_ids(file_ids, batch: int) -> np.ndarray:
    if file_ids is None:
        raise ValueError("this engine requires file_ids for insert_batch")
    arr = np.atleast_1d(np.asarray(file_ids, dtype=np.int32))
    if arr.shape != (batch,):
        raise ValueError(f"file_ids shape {arr.shape} != batch ({batch},)")
    return arr


# ---------------------------------------------------------------------------
# Partitioned Bloom filter.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackedBloomIndex(_StateView):
    """Single-set partitioned BF over any registered hash scheme."""

    cfg: idl_mod.IDLConfig
    scheme: str = "idl"
    words: Optional[jax.Array] = None     # (m/32,) uint32

    def __post_init__(self):
        if self.cfg.m % 32:
            raise ValueError(f"m={self.cfg.m} must be a multiple of 32")
        if self.words is None:
            object.__setattr__(
                self, "words", jnp.zeros((self.cfg.m // 32,), dtype=jnp.uint32)
            )

    @classmethod
    def build(cls, cfg: idl_mod.IDLConfig, scheme: str = "idl") -> "PackedBloomIndex":
        return cls(cfg=cfg, scheme=scheme)

    def insert_batch(self, reads, file_ids=None, **kw) -> "PackedBloomIndex":
        """Index a (B, read_len) batch; ``file_ids`` is ignored (single set).

        Keyword args pick the shared ingest executor (see
        :mod:`repro.index.ingest`): ``backend`` in {"jnp", "idl_insert",
        "sharded"}, plus ``mesh`` / ``interpret`` / ``use_ref`` /
        ``window_min`` passthroughs. All backends are bit-identical
        (``window_min`` sub-sampling excepted — it inserts fewer kmers).
        ``donate=True`` (default) donates this value's buffer and marks it
        consumed — keep only the returned index; ``donate=False`` keeps
        this value usable (one extra copy).
        """
        del file_ids
        state_mod.ensure_live(self, self.words, what="engine")
        reads = _as_batch(reads)
        plan = ingest.plan_insert(
            self.cfg, self.scheme, reads.shape, (self.cfg.m // 32, 1),
            kind="bits", window_min=kw.pop("window_min", None),
        )
        donate = kw.pop("donate", True)
        words = plan.execute(self.words, reads, donate=donate, **kw)
        if donate:
            state_mod.mark_consumed(self)
        return dataclasses.replace(self, words=words)

    def _plan(self, reads: jax.Array) -> query.QueryPlan:
        return query.plan_query(
            self.cfg, self.scheme, reads.shape,
            (self.cfg.m // 32, 1), bit_probe=True,
        )

    def query_batch(self, reads, *, backend: str = "jnp", **kw) -> jax.Array:
        """(B, n_kmers) bool per-kmer membership.

        ``backend`` picks the shared query executor (see
        :mod:`repro.index.query`): ``"jnp"``, ``"idl_probe"`` (host
        run-length planner + Pallas kernel; kw ``interpret`` forces or
        disables Pallas interpreter mode, defaulting to interpret on CPU;
        kw ``use_ref`` swaps in the kernel's fused jnp oracle) or
        ``"sharded"`` (``shard_map`` over kw ``mesh``, default the full
        1-D device mesh).
        """
        state_mod.ensure_live(self, self.words, what="engine")
        reads = _as_batch(reads)
        vals = self._plan(reads).execute(
            self.words, reads, backend=backend, **kw
        )
        return vals[..., 0] == 1

    def msmt(self, reads, theta: float = 1.0, **kw) -> jax.Array:
        """(B,) bool: kmer-coverage of the one indexed set >= theta."""
        return query.member_coverage(self.query_batch(reads, **kw), theta)

    @property
    def bits(self) -> jax.Array:
        """Compatibility view: (m,) uint8 bit-per-byte layout."""
        from repro.core import bloom as bloom_mod

        return bloom_mod.unpack_bits(self.words)

    @property
    def fill_fraction(self) -> jax.Array:
        return jnp.mean(self.bits.astype(jnp.float32))


# ---------------------------------------------------------------------------
# COBS — compact bit-sliced signature index (size-grouped).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CobsGroupState:
    """One size-group: files sharing a filter size ``cfg.m``."""

    cfg: idl_mod.IDLConfig
    file_ids: tuple[int, ...]
    words: Optional[jax.Array] = None     # (m_g, ceil(n_files/32)) uint32

    def __post_init__(self):
        if self.words is None:
            w = -(-len(self.file_ids) // 32)
            object.__setattr__(
                self, "words", jnp.zeros((self.cfg.m, w), dtype=jnp.uint32)
            )


@dataclasses.dataclass(frozen=True)
class CobsIndex(_StateView):
    """Size-grouped bit-sliced filters over N files (BIGSI/COBS layout)."""

    groups: tuple[CobsGroupState, ...]
    scheme: str
    n_files: int
    k: int

    def __post_init__(self):
        ks = {g.cfg.k for g in self.groups}
        if not self.groups:
            raise ValueError("CobsIndex needs at least one group")
        if ks != {self.k}:
            raise ValueError(f"groups disagree on k: {sorted(ks)} vs k={self.k}")

    @classmethod
    def build(
        cls,
        file_sizes: Sequence[int],
        base_cfg: idl_mod.IDLConfig,
        scheme: str = "idl",
        bits_per_kmer: float = 10.0,
        n_groups: int = 2,
    ) -> "CobsIndex":
        """Group files by kmer count; m_g sized from the group's largest file."""
        if len(file_sizes) == 0:
            raise ValueError("CobsIndex.build needs at least one file")
        order = np.argsort(file_sizes)
        chunks = np.array_split(order, n_groups)
        groups = []
        for chunk in chunks:
            if len(chunk) == 0:
                continue
            biggest = max(int(file_sizes[i]) for i in chunk)
            m_g = _round_up(int(bits_per_kmer * biggest), 1 << 12)
            m_g = max(m_g, base_cfg.eta * (base_cfg.L * 2))
            cfg = dataclasses.replace(base_cfg, m=m_g)
            groups.append(
                CobsGroupState(cfg=cfg, file_ids=tuple(int(i) for i in chunk))
            )
        return cls(groups=tuple(groups), scheme=scheme,
                   n_files=len(file_sizes), k=base_cfg.k)

    def _slot(self, file_id: int) -> tuple[int, int]:
        for gi, g in enumerate(self.groups):
            if file_id in g.file_ids:
                return gi, g.file_ids.index(file_id)
        raise KeyError(f"file {file_id} not in any group")

    def insert_batch(self, reads, file_ids=None, **kw) -> "CobsIndex":
        """Index reads into their files' group columns (one scatter/group).

        Keyword args pick the shared ingest executor (see
        :mod:`repro.index.ingest`); ``donate=False`` keeps this value
        usable after the insert.
        """
        state_mod.ensure_live(self, *(g.words for g in self.groups),
                              what="engine")
        reads = _as_batch(reads)
        fids = _as_file_ids(file_ids, reads.shape[0])
        window_min = kw.pop("window_min", None)
        donate = kw.pop("donate", True)
        slots = [self._slot(int(f)) for f in fids]
        groups = list(self.groups)
        for gi in sorted({gi for gi, _ in slots}):
            sel = np.array([i for i, (g, _) in enumerate(slots) if g == gi])
            cols = jnp.asarray(
                np.array([slots[i][1] for i in sel], dtype=np.int32))
            g = groups[gi]
            sub = jnp.take(reads, jnp.asarray(sel), axis=0)
            plan = ingest.plan_insert(
                g.cfg, self.scheme, sub.shape, g.words.shape,
                kind="cols", window_min=window_min,
            )
            words = plan.execute(g.words, sub, cols, donate=donate, **kw)
            groups[gi] = dataclasses.replace(g, words=words)
        if donate:
            state_mod.mark_consumed(self)
        return dataclasses.replace(self, groups=tuple(groups))

    def query_batch(self, reads, *, backend: str = "jnp", **kw) -> jax.Array:
        """(B, n_kmers, n_files) bool MSMT kmer slices (Definition 3)."""
        state_mod.ensure_live(self, *(g.words for g in self.groups),
                              what="engine")
        reads = _as_batch(reads)
        n_k = reads.shape[1] - self.k + 1
        out = jnp.zeros((reads.shape[0], n_k, self.n_files), dtype=bool)
        for g in self.groups:
            plan = query.plan_query(
                g.cfg, self.scheme, reads.shape, g.words.shape,
                bit_probe=False,
            )
            masks = plan.execute(g.words, reads, backend=backend, **kw)
            sl = packed.unpack_file_bits(masks, len(g.file_ids))
            out = out.at[:, :, jnp.asarray(g.file_ids)].set(sl)
        return out

    def msmt(self, reads, theta: float = 1.0, **kw) -> jax.Array:
        """(B, n_files) bool: per-file kmer-coverage >= theta."""
        return query.member_coverage(self.query_batch(reads, **kw), theta)

    @property
    def total_bits(self) -> int:
        return sum(int(g.cfg.m) * len(g.file_ids) for g in self.groups)


# ---------------------------------------------------------------------------
# RAMBO — repeated and merged bucketed Bloom filters.
# ---------------------------------------------------------------------------

def rambo_dimensions(
    n_files: int, B: Optional[int] = None, R: Optional[int] = None
) -> tuple[int, int]:
    """Default RAMBO shape: B = O(sqrt N) buckets, R = O(log N) repetitions."""
    if B is None:
        B = max(2, int(np.ceil(np.sqrt(n_files))))
    if R is None:
        R = max(2, int(np.ceil(np.log2(max(n_files, 2)))))
    return B, R


def rambo_assignment(n_files: int, n_buckets: int, n_rep: int) -> np.ndarray:
    """(R, N) int32 file->bucket map (same hash family as the query path)."""
    files = np.arange(n_files, dtype=np.uint64)
    return np.stack(
        [
            hashing.np_hash_to_range(files, 0xA3B0 + r, n_buckets).astype(np.int32)
            for r in range(n_rep)
        ],
        axis=0,
    )


@dataclasses.dataclass(frozen=True)
class RamboIndex(_StateView):
    """B buckets × R repetitions of merged BFs; sub-linear MSMT."""

    cfg: idl_mod.IDLConfig                 # cfg.m = bits per bucket BF
    scheme: str
    n_files: int
    n_buckets: int                         # B
    n_rep: int                             # R
    words: Optional[jax.Array] = None      # (R*B, m/32) uint32
    assignment: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.cfg.m % 32:
            raise ValueError(f"bucket size m={self.cfg.m} must be a multiple of 32")
        if self.words is None:
            object.__setattr__(
                self, "words",
                jnp.zeros((self.n_rep * self.n_buckets, self.cfg.m // 32),
                          dtype=jnp.uint32),
            )
        if self.assignment is None:
            object.__setattr__(
                self, "assignment",
                rambo_assignment(self.n_files, self.n_buckets, self.n_rep),
            )

    @classmethod
    def build(
        cls, n_files: int, cfg: idl_mod.IDLConfig, scheme: str = "idl",
        B: Optional[int] = None, R: Optional[int] = None,
    ) -> "RamboIndex":
        B, R = rambo_dimensions(n_files, B, R)
        return cls(cfg=cfg, scheme=scheme, n_files=n_files,
                   n_buckets=B, n_rep=R)

    def _filter_rows(self, fids: np.ndarray) -> jax.Array:
        offs = np.arange(self.n_rep, dtype=np.int32) * self.n_buckets
        return jnp.asarray(self.assignment[:, fids].T + offs[None, :])  # (B, R)

    @property
    def _words_t(self) -> jax.Array:
        """(m/32, R·B) transposed view for the query layer, materialized
        once per index value (insert_batch returns a fresh instance, so the
        cache can never alias a donated buffer)."""
        cached = getattr(self, "_words_t_cache", None)
        if cached is None or cached[0] is not self.words:
            cached = (self.words, jnp.asarray(self.words.T))
            object.__setattr__(self, "_words_t_cache", cached)
        return cached[1]

    def insert_batch(self, reads, file_ids=None, **kw) -> "RamboIndex":
        """Index reads into their R bucket filters (shared ingest layer)."""
        state_mod.ensure_live(self, self.words, what="engine")
        reads = _as_batch(reads)
        fids = _as_file_ids(file_ids, reads.shape[0])
        plan = ingest.plan_insert(
            self.cfg, self.scheme, reads.shape, self.words.shape,
            kind="rows", window_min=kw.pop("window_min", None),
        )
        donate = kw.pop("donate", True)
        words = plan.execute(self.words, reads, self._filter_rows(fids),
                             donate=donate, **kw)
        if donate:
            state_mod.mark_consumed(self)
        return dataclasses.replace(self, words=words)

    def query_grid(self, reads, *, backend: str = "jnp", **kw) -> jax.Array:
        """(B, n_kmers, R, buckets) bool: bucket hits per kmer.

        The R·B stacked filters are probed as ONE transposed
        ``(m/32, R·B)`` bit-matrix: every location resolves all buckets'
        bits from a single gathered row of the shared query layer.
        """
        state_mod.ensure_live(self, self.words, what="engine")
        reads = _as_batch(reads)
        rb = self.n_rep * self.n_buckets
        plan = query.plan_query(
            self.cfg, self.scheme, reads.shape,
            (self.cfg.m // 32, rb), bit_probe=True,
        )
        vals = plan.execute(
            self._words_t, reads, backend=backend, **kw
        )                                                 # (B, n_k, RB) {0,1}
        return (vals == 1).reshape(
            vals.shape[0], vals.shape[1], self.n_rep, self.n_buckets
        )

    def query_batch(self, reads, *, backend: str = "jnp", **kw) -> jax.Array:
        """(B, n_kmers, n_files) bool: file present in all R of its buckets."""
        grid = self.query_grid(reads, backend=backend, **kw)  # (B, n_k, R, Bkt)
        idx = jnp.asarray(self.assignment)[None, None]    # (1, 1, R, N)
        per_rep = jnp.take_along_axis(grid, idx, axis=3)  # (B, n_k, R, N)
        return jnp.all(per_rep, axis=2)

    def msmt(self, reads, theta: float = 1.0, **kw) -> jax.Array:
        return query.member_coverage(self.query_batch(reads, **kw), theta)

    @property
    def total_bits(self) -> int:
        return int(self.words.shape[0]) * int(self.words.shape[1]) * 32


# ---------------------------------------------------------------------------
# Bit-sliced serving index (the paper's system; 32-bit lane path).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BitSlicedIndex(_StateView):
    """One bit-sliced (m, F/32) matrix queried on the TPU 32-bit lane path."""

    cfg: idl_mod.IDLConfig
    scheme: str
    n_files: int
    words: Optional[jax.Array] = None      # (m, ceil(n_files/32)) uint32

    def __post_init__(self):
        if self.words is None:
            w = -(-self.n_files // 32)
            object.__setattr__(
                self, "words", jnp.zeros((self.cfg.m, w), dtype=jnp.uint32)
            )

    @classmethod
    def build(
        cls, cfg: idl_mod.IDLConfig, scheme: str = "idl", n_files: int = 1024
    ) -> "BitSlicedIndex":
        return cls(cfg=cfg, scheme=scheme, n_files=n_files)

    def insert_batch(self, reads, file_ids=None, **kw) -> "BitSlicedIndex":
        """Index reads into their file columns (shared ingest layer)."""
        state_mod.ensure_live(self, self.words, what="engine")
        reads = _as_batch(reads)
        fids = _as_file_ids(file_ids, reads.shape[0])
        plan = ingest.plan_insert(
            self.cfg, self.scheme, reads.shape, self.words.shape,
            kind="cols", lane32=True, window_min=kw.pop("window_min", None),
        )
        donate = kw.pop("donate", True)
        words = plan.execute(self.words, reads, jnp.asarray(fids),
                             donate=donate, **kw)
        if donate:
            state_mod.mark_consumed(self)
        return dataclasses.replace(self, words=words)

    def query_batch(self, reads, *, backend: str = "jnp", **kw) -> jax.Array:
        """(B, n_kmers, F/32) uint32 per-kmer file masks (packed)."""
        state_mod.ensure_live(self, self.words, what="engine")
        reads = _as_batch(reads)
        plan = query.plan_query(
            self.cfg, self.scheme, reads.shape, self.words.shape,
            bit_probe=False, lane32=True,
        )
        return plan.execute(self.words, reads, backend=backend, **kw)

    def msmt(self, reads, theta: float = 1.0, **kw) -> jax.Array:
        """(B, n_files) bool — the serve-layout MSMT (one theta rule)."""
        per_kmer = self.query_batch(reads, **kw)          # (B, n_k, W)
        mask = query.file_match_mask(per_kmer, theta)     # (B, W)
        return packed.unpack_file_bits(mask, self.n_files)


def _round_up(x: int, align: int) -> int:
    return -(-x // align) * align

"""The shared ingest layer: one planned insert path for every engine.

Mirror of :mod:`repro.index.query` for the write side. The paper's claim is
that IDL speeds up *indexing and* query of COBS/RAMBO-style systems, and
RAMBO's pitch is whole-archive ingest measured in hours, not weeks — so the
build path gets the same treatment the query path got: every engine's
insert is a **scatter-OR of single bits into a packed ``(n_rows, W)``
uint32 bit-matrix**, described by ``(row, word_col, bit)`` targets —

======================  ==========================  ======================
Engine                  Target matrix               Target derivation
======================  ==========================  ======================
``PackedBloomIndex``    ``(m/32, 1)`` word column   ``(loc>>5, 0, loc&31)``
``RamboIndex``          ``(R·B, m/32)`` stack       ``(bucket_row, loc>>5, loc&31)``
``CobsIndex`` group     ``(m_g, ⌈F_g/32⌉)``         ``(loc, col>>5, col&31)``
``BitSlicedIndex``      ``(m, ⌈F/32⌉)``             ``(loc, col>>5, col&31)``
======================  ==========================  ======================

An :class:`InsertPlan` holds everything static — config, scheme, read
shape, matrix geometry, the run-coalescing tile height — and is built once
per ``(cfg, scheme, read_shape, matrix_shape)`` through an LRU cache
(:func:`plan_insert`). Executing a plan picks one of three backends:

* ``"jnp"``        — one jit-compiled, donated, sort-deduplicated scatter
  for the whole batch (the reference; the single implementation that
  replaced the three divergent scatter bodies in ``packed.py``);
* ``"idl_insert"`` — the host-side run-length planner + the generalized
  Pallas ``insert_runs`` kernel: the batch's targets are sorted,
  deduplicated and run-length-encoded by matrix row-block, each touched
  block costs ONE ``(rows_per_block, W)`` tile read + write (consecutive
  runs accumulate into the resident tile), and the whole batch executes as
  a single kernel launch with a donated destination;
* ``"sharded"``    — ``shard_map`` over a 1-D device mesh. Bit-scatter
  layouts (flat BF) split the words axis; row/column-scatter layouts
  (RAMBO word columns, COBS/bit-sliced file-words) split the W axis. Each
  shard drops the targets that are not its own — scatter-OR commutes, so
  there is no cross-shard traffic at all.

All backends are bit-identical; ``tests/test_ingest.py`` holds the parity
matrix. On top, :func:`build_archive` streams a whole archive of genome
files through the planner chunk-by-chunk (optional ``window_min``
minimizer sub-sampling), so an archive build is one Python loop of
jit-compiled donated inserts.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import hashing, idl as idl_mod, minhash
from repro.index import packed, query

BACKENDS = ("jnp", "idl_insert", "sharded")
KINDS = ("bits", "rows", "cols")
MESH_AXIS = query.MESH_AXIS


# ---------------------------------------------------------------------------
# Minimizer sub-sampling (optional archive-build densification knob).
# ---------------------------------------------------------------------------

def minimizer_mask(locs: jax.Array, w: int) -> jax.Array:
    """(B, n_kmers) bool: kmer is a window-``w`` minimizer of its read.

    The rank is a re-mix of the kmer's first-repetition location (so it is
    deterministic from the kmer, decorrelated from the probe address). A
    kmer is kept iff it attains the minimum rank of at least one length-w
    window containing it — the standard minimizer rule, computed with two
    Gil–Werman sliding minima (the second over inverted ranks = sliding
    max of the per-window minima). Reads shorter than w keep everything.
    """
    rank = hashing.mix32(locs[:, 0, :] ^ jnp.uint32(0x9E3779B9))
    n_k = rank.shape[1]
    if w <= 1 or n_k < w:
        return jnp.ones(rank.shape, dtype=bool)
    sw = jax.vmap(lambda r: minhash.sliding_window_min(r, w))(rank)
    inv = ~sw
    pad = jnp.full((inv.shape[0], w - 1), 0xFFFFFFFF, dtype=jnp.uint32)
    invp = jnp.concatenate([pad, inv, pad], axis=1)
    best = ~jax.vmap(lambda r: minhash.sliding_window_min(r, w))(invp)
    return best == rank


# ---------------------------------------------------------------------------
# The plan.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InsertPlan:
    """Static insert recipe for one (cfg, scheme, read_shape, matrix) tuple.

    ``kind`` names how a read's hash locations become (row, word, bit)
    targets: ``"bits"`` — locations are flat bit offsets of a packed word
    column (flat BF); ``"rows"`` — each read lands in aux filter rows and
    locations pick (word, bit) within the row (RAMBO); ``"cols"`` — each
    read owns an aux file column and locations pick the matrix row
    (bit-sliced COBS/serving layouts).
    """

    cfg: idl_mod.IDLConfig
    scheme: str
    read_shape: tuple[int, int]       # (B, read_len)
    matrix_shape: tuple[int, int]     # (n_rows, W)
    kind: str
    lane32: bool
    rows_per_block: int               # run-coalescing DMA tile height
    inserts_per_run: int
    window_min: Optional[int] = None  # minimizer sub-sampling window

    @property
    def batch(self) -> int:
        return self.read_shape[0]

    @property
    def n_kmers(self) -> int:
        return self.read_shape[1] - self.cfg.k + 1

    @property
    def n_rows(self) -> int:
        return self.matrix_shape[0]

    @property
    def row_words(self) -> int:
        return self.matrix_shape[1]

    @property
    def block_bits(self) -> int:
        """Bits per DMA tile in the flattened (rows*W*32) bit space."""
        return self.rows_per_block * self.row_words * 32

    @property
    def block_bytes(self) -> int:
        """HBM bytes one tile DMA moves — the quantity IDL minimizes."""
        return self.block_bits // 8

    # -- target stream (shared by every backend) ----------------------------
    def locations(self, reads: jax.Array) -> jax.Array:
        """(B, η, n_kmers) uint32 hash locations (the query layer's body)."""
        return query.batch_locations(
            reads, cfg=self.cfg, scheme=self.scheme, lane32=self.lane32
        )

    def targets(self, reads: jax.Array, aux: Optional[jax.Array] = None):
        """Flat (row, word_col, bit) int32/uint32 target streams.

        Targets masked off (minimizer sub-sampling) are routed to the
        out-of-range row ``n_rows`` and dropped by every backend's scatter.
        ``aux``: None (``"bits"``), (B, R) filter rows (``"rows"``), or
        (B,) file columns (``"cols"``).
        """
        locs = self.locations(reads)                    # (B, η, n_k)
        oob = jnp.int32(self.n_rows)
        keep = None
        if self.window_min is not None:
            keep = minimizer_mask(locs, self.window_min)
        if self.kind == "bits":
            row = (locs >> jnp.uint32(5)).astype(jnp.int32)
            wc = jnp.zeros_like(row)
            bit = locs & jnp.uint32(31)
            if keep is not None:
                row = jnp.where(keep[:, None, :], row, oob)
        elif self.kind == "cols":
            if aux is None:
                raise ValueError("kind='cols' plans need (B,) file columns")
            cols = aux.reshape(-1).astype(jnp.int32)    # (B,)
            row = locs.astype(jnp.int32)
            wc = jnp.broadcast_to((cols >> 5)[:, None, None], row.shape)
            bit = jnp.broadcast_to(
                (cols & 31).astype(jnp.uint32)[:, None, None], row.shape)
            if keep is not None:
                row = jnp.where(keep[:, None, :], row, oob)
        elif self.kind == "rows":
            if aux is None:
                raise ValueError("kind='rows' plans need (B, R) filter rows")
            frows = aux.astype(jnp.int32)               # (B, R)
            shape = frows.shape + locs.shape[1:]        # (B, R, η, n_k)
            row = jnp.broadcast_to(frows[:, :, None, None], shape)
            wc = jnp.broadcast_to(
                (locs >> jnp.uint32(5)).astype(jnp.int32)[:, None], shape)
            bit = jnp.broadcast_to((locs & jnp.uint32(31))[:, None], shape)
            if keep is not None:
                row = jnp.where(keep[:, None, None, :], row, oob)
        else:
            raise ValueError(f"unknown insert kind {self.kind!r}")
        return row.reshape(-1), wc.reshape(-1), bit.reshape(-1)

    def plan_runs(self, reads: jax.Array, aux: Optional[jax.Array] = None):
        """Host-side sorted/deduplicated run plan (ONE kernel launch)."""
        from repro.kernels.idl_insert import ops as ins_ops

        row, wc, bit = (np.asarray(t, dtype=np.int64)
                        for t in self.targets(reads, aux))
        flat = (row * self.row_words + wc) * 32 + bit
        flat[row >= self.n_rows] = -1                   # masked targets
        return ins_ops.plan_insert_runs(
            flat, block_bits=self.block_bits,
            inserts_per_run=self.inserts_per_run,
        )

    def run_dma_bytes(self, rplan) -> int:
        """Tile bytes the plan DMAs (read + write per touched block)."""
        return 0 if rplan is None else rplan.dma_bytes

    # -- execution ----------------------------------------------------------
    def execute(
        self,
        matrix: jax.Array,
        reads: jax.Array,
        aux: Optional[jax.Array] = None,
        *,
        backend: str = "jnp",
        interpret: Optional[bool] = None,
        use_ref: bool = False,
        mesh: Optional[Mesh] = None,
        donate: bool = True,
    ) -> jax.Array:
        """Scatter-OR the batch into ``matrix``; returns the updated matrix.

        ``matrix`` may be 1-D when ``W == 1`` (flat packed BF); the result
        always has the input's shape. The destination buffer is donated on
        the ``jnp`` and ``idl_insert`` backends — use linearly, or pass
        ``donate=False`` to scatter into a private copy and keep the input
        buffer alive (one extra device copy; same compiled executable).
        """
        if not donate:
            matrix = jnp.array(matrix, copy=True)
        if backend == "jnp":
            return _execute_jnp(matrix, reads, aux, plan=self)
        if backend == "idl_insert":
            return self._execute_idl_insert(matrix, reads, aux,
                                            interpret, use_ref)
        if backend == "sharded":
            if mesh is None:
                mesh = query.default_mesh()
            return _sharded_inserter(self, mesh)(matrix, reads, aux)
        raise ValueError(
            f"unknown ingest backend {backend!r} (want one of {BACKENDS})"
        )

    def _execute_idl_insert(self, matrix, reads, aux, interpret, use_ref):
        from repro.kernels.idl_insert import ops as ins_ops

        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        rplan = self.plan_runs(reads, aux)
        if rplan is not None:
            query.record_locality(
                scheme=self.scheme, op="insert",
                tile_bytes=self.run_dma_bytes(rplan),
                n_runs=rplan.n_runs, n_probes=int(rplan.n_locs),
                run_lengths=rplan.run_lengths)
        return ins_ops.insert_planned(
            matrix, rplan, interpret=interpret, use_ref=use_ref,
        )


# Bounded like query.PLAN_CACHE_SIZE (same argument: plans are frozen
# value objects, jitted executors key on plan hash/eq, so eviction never
# costs a recompile — asserted in tests/test_query_dedup.py).
PLAN_CACHE_SIZE = 512


@functools.lru_cache(maxsize=PLAN_CACHE_SIZE)
def plan_insert(
    cfg: idl_mod.IDLConfig,
    scheme: str,
    read_shape: tuple[int, int],
    matrix_shape: tuple[int, int],
    *,
    kind: str,
    lane32: bool = False,
    rows_per_block: Optional[int] = None,
    inserts_per_run: Optional[int] = None,
    window_min: Optional[int] = None,
) -> InsertPlan:
    """Build (or fetch) the cached plan for one insert geometry.

    ``rows_per_block`` defaults to the IDL locality window ``cfg.L``
    translated to matrix rows (``L/32`` packed words for ``"bits"``; for
    row/column targets, ``L`` rows clamped so one tile's f32 bit image
    stays VMEM-friendly), as a power of two that divides ``n_rows``.
    ``inserts_per_run`` defaults to the TPU lane width (128); 32 on a CPU
    host, where narrow runs waste fewer pad lanes.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown insert kind {kind!r} (want one of {KINDS})")
    n_rows, row_words = matrix_shape
    if inserts_per_run is None:
        inserts_per_run = 32 if jax.default_backend() == "cpu" else 128
    if rows_per_block is None:
        if kind == "bits":
            target = max(cfg.L // 32, 1)
        else:
            # keep one DMA tile's unpacked f32 bit image ~<= 2 MB
            target = max(1, min(cfg.L, (1 << 21) // max(row_words * 128, 1)))
        rows_per_block = query._pow2_block(n_rows, target)
    if n_rows % rows_per_block:
        raise ValueError(
            f"rows_per_block={rows_per_block} must divide n_rows={n_rows}"
        )
    return InsertPlan(
        cfg=cfg, scheme=scheme,
        read_shape=tuple(read_shape), matrix_shape=tuple(matrix_shape),
        kind=kind, lane32=lane32,
        rows_per_block=rows_per_block, inserts_per_run=inserts_per_run,
        window_min=window_min,
    )


def plan_cache_info() -> "query.PlanCacheInfo":
    """Stats of the (bounded) insert-plan cache, incl. eviction count."""
    return query._with_evictions(plan_insert.cache_info())


def clear_plan_cache() -> None:
    plan_insert.cache_clear()


# ---------------------------------------------------------------------------
# Backend bodies.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("plan",))
def _execute_jnp(matrix, reads, aux, *, plan: InsertPlan):
    shape = matrix.shape
    row, wc, bit = plan.targets(reads, aux)
    if plan.kind == "bits":
        # W == 1: the flat location is one sort key — skip the 3-key
        # lexsort (masked rows land out of range and are dropped)
        flat = (row.astype(jnp.uint32) << jnp.uint32(5)) | bit
        words = packed.scatter_or(jnp.reshape(matrix, (-1,)), flat)
        return words.reshape(shape)
    mat = jnp.reshape(matrix, plan.matrix_shape)
    return packed.scatter_or_matrix(mat, row, wc, bit).reshape(shape)


# Bounded; eviction HERE drops a compiled closure (cold re-entry
# recompiles) — 128 keeps realistic working sets hot (see query.py).
@functools.lru_cache(maxsize=128)
def _sharded_inserter(plan: InsertPlan, mesh: Mesh):
    """jit-compiled shard_map inserter for one (plan, mesh) pair.

    ``"bits"`` plans split the words (row) axis; ``"rows"``/``"cols"``
    plans split the W axis (RAMBO's m-words / the file-words of bit-sliced
    layouts — the serving sharding). Every shard recomputes the target
    stream, keeps only its own slice's targets, and scatters locally:
    scatter-OR commutes, so no collective is needed at all.
    """
    n_shards = int(np.prod(mesh.devices.shape))
    n_rows, w = plan.matrix_shape
    split_rows = plan.kind == "bits"
    per_shard = -(-(n_rows if split_rows else w) // n_shards)
    pad = per_shard * n_shards - (n_rows if split_rows else w)

    def body(mat, reads, aux):
        row, wc, bit = plan.targets(reads, aux)
        lo = jax.lax.axis_index(MESH_AXIS).astype(jnp.int32) * per_shard
        if split_rows:
            local = (row >= lo) & (row < lo + per_shard)
            row = jnp.where(local, row - lo, per_shard)     # oob -> dropped
        else:
            local = (wc >= lo) & (wc < lo + per_shard)
            wc = jnp.where(local, wc - lo, per_shard)
        return packed.scatter_or_matrix(mat, row, wc, bit)

    mat_spec = P(MESH_AXIS, None) if split_rows else P(None, MESH_AXIS)
    aux_spec = P() if plan.kind != "bits" else None

    def run(matrix, reads, aux):
        shape = matrix.shape
        mat = jnp.reshape(matrix, plan.matrix_shape)
        if pad:
            mat = jnp.pad(
                mat, ((0, pad), (0, 0)) if split_rows else ((0, 0), (0, pad)))
        if aux_spec is None:
            out = shard_map(
                lambda m, r: body(m, r, None), mesh=mesh,
                in_specs=(mat_spec, P()), out_specs=mat_spec,
            )(mat, reads)
        else:
            out = shard_map(
                body, mesh=mesh,
                in_specs=(mat_spec, P(), aux_spec), out_specs=mat_spec,
            )(mat, reads, aux)
        if pad:
            out = out[:n_rows] if split_rows else out[:, :w]
        return out.reshape(shape)

    return jax.jit(run, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Streaming archive builder.
# ---------------------------------------------------------------------------

def _engine_k(index) -> int:
    k = getattr(index, "k", None)
    if k is None:
        k = index.cfg.k
    return int(k)


def _file_sequences(item, default_id: int):
    """Normalize an archive item to (file_id, [code arrays])."""
    from repro.data import genome as genome_mod

    if isinstance(item, genome_mod.GenomeFile):
        return item.file_id, [np.asarray(item.genome)]
    if isinstance(item, str):
        return default_id, [
            np.asarray(codes)
            for codes in genome_mod.read_fasta(item).values()
        ]
    fid, codes = item
    return int(fid), [np.asarray(codes)]


def build_archive(
    index,
    files: Iterable,
    *,
    read_len: int = 230,
    chunk_reads: int = 64,
    backend: str = "jnp",
    mesh: Optional[Mesh] = None,
    window_min: Optional[int] = None,
    pad_final: bool = True,
    **kw,
):
    """Stream a whole archive into any ``GeneIndex`` engine.

    ``files``: an iterable of ``data.genome.GenomeFile``, ``(file_id,
    codes)`` pairs, or FASTA paths (each path is one file; its records are
    kmerized separately, numbered by position). Every sequence is chopped
    into fixed-``read_len`` windows overlapping by ``k - 1`` bases — every
    kmer is covered, and the duplicate boundary kmers are free because
    scatter-OR is idempotent. Windows are batched ``chunk_reads`` at a
    time and fed to the engine's ``insert_batch`` with the chosen ingest
    backend, so the whole build is one Python loop of jit-compiled,
    donated inserts (with ``pad_final``, partial tail chunks are padded by
    repeating a read — idempotent again — so each window length compiles
    exactly once).

    ``window_min`` enables minimizer sub-sampling (insert only window-w
    minimizer kmers — a build-size/FPR trade, NOT bit-identical to a full
    build). Returns the updated engine (use linearly: buffers are donated).
    """
    from repro.data import genome as genome_mod

    k = _engine_k(index)
    pending: dict[int, tuple[list, list]] = {}

    def flush(length: int, force: bool):
        nonlocal index
        reads_l, fids_l = pending[length]
        while len(reads_l) >= chunk_reads or (force and reads_l):
            take = min(chunk_reads, len(reads_l))
            batch, fids = reads_l[:take], fids_l[:take]
            del reads_l[:take], fids_l[:take]
            if pad_final and take < chunk_reads:
                batch = batch + [batch[0]] * (chunk_reads - take)
                fids = fids + [fids[0]] * (chunk_reads - take)
            index = index.insert_batch(
                jnp.asarray(np.stack(batch)),
                np.asarray(fids, dtype=np.int32),
                backend=backend, mesh=mesh, window_min=window_min, **kw,
            )

    for pos, item in enumerate(files):
        fid, seqs = _file_sequences(item, pos)
        for codes in seqs:
            windows = genome_mod.window_reads(codes, read_len, k)
            if windows.shape[0] == 0:
                continue
            length = windows.shape[1]
            reads_l, fids_l = pending.setdefault(length, ([], []))
            reads_l.extend(windows)
            fids_l.extend([fid] * windows.shape[0])
            flush(length, force=False)
    for length in sorted(pending):
        flush(length, force=True)
    return index


def build_sharded_archive(
    index,
    files: Iterable,
    *,
    n_shards: int,
    out_dir: Optional[str] = None,
    read_len: int = 230,
    chunk_reads: int = 64,
    backend: str = "jnp",
    window_min: Optional[int] = None,
    pad_final: bool = True,
    set_version: int = 0,
):
    """Partition an empty engine/state and stream the archive into every
    shard in parallel — one thread per shard over the same donated insert
    planner :func:`build_archive` uses (each shard compiles its plan
    once; under jax the scatters release the GIL, so shard builds overlap
    wherever the host has cores).

    Row-probe shards (bit-sliced / cobs) each ingest only their own file
    range — bit-sliced file ids are renumbered into the shard-local
    column space. Bit-probe shards (flat BF / rambo) each ingest EVERY
    read through a :class:`repro.index.shards.ShardBuilder`, which keeps
    only the targets in the shard's word range (scatter-OR commutes and
    is idempotent, so dropping foreign targets is exact). Joining the
    result is bit-identical to the unsharded ``build_archive`` — asserted
    in tests/test_shards.py.

    Returns ``(spec, [IndexState, ...])``; with ``out_dir`` also writes
    the shard-set snapshot (``shards.save_shard_set``) stamped
    ``set_version``.
    """
    from repro.index import shards as shards_mod
    from repro.index import state as state_mod

    spec, parts = shards_mod.partition_state(index, n_shards)
    items = []
    for pos, item in enumerate(files):
        fid, seqs = _file_sequences(item, pos)
        items.extend((fid, codes) for codes in seqs)
    build_kw = dict(read_len=read_len, chunk_reads=chunk_reads,
                    window_min=window_min, pad_final=pad_final)
    results: list = [None] * n_shards
    errors: list = []

    def run(s: int) -> None:
        try:
            if spec.row_probe:
                owned = shards_mod.shard_files(spec, s)
                base = owned[0] if (
                    owned and spec.meta.engine == "bitsliced") else 0
                own = set(owned)
                mine = [(fid - base, codes)
                        for fid, codes in items if fid in own]
                built = build_archive(
                    state_mod.to_engine(parts[s]), mine,
                    backend=backend, **build_kw)
                results[s] = state_mod.from_engine(built)
            else:
                builder = shards_mod.ShardBuilder(spec, s, parts[s])
                built = build_archive(builder, items,
                                      backend=backend, **build_kw)
                results[s] = built.state
        except Exception as e:  # noqa: BLE001 - surfaced to the caller
            errors.append((s, e))

    threads = [threading.Thread(target=run, args=(s,),
                                name=f"idl-shard-build-{s}")
               for s in range(n_shards)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        s, e = min(errors)
        raise RuntimeError(f"shard {s} build failed: {e!r}") from e
    if out_dir is not None:
        shards_mod.save_shard_set(spec, results, out_dir,
                                  version=set_version)
    return spec, results

"""Functional index state — the pytree core of the ``GeneIndex`` v2 API.

Every engine's storage is a set of packed ``(n_rows, W)`` uint32 matrices
plus static geometry. :class:`IndexState` makes that explicit: the word
matrices are pytree *leaves* (so a state jits, shards, donates and
checkpoints like any other JAX value) and everything static — config,
scheme, file grouping, RAMBO shape — lives in a hashable
:class:`StateMeta` carried as aux data. On top sit three pure functions::

    new_state = insert(state, reads, file_ids)   # linear: consumes `state`
    member    = query(state, reads)
    verdicts  = msmt(state, reads, theta)

The engine classes (:mod:`repro.index.engines`) are thin *views* over a
state: ``engine.state`` extracts it, ``engine.with_state(s)`` /
:func:`to_engine` rebuild a view, and both directions are loss-free for
all four engines (``tests/test_state.py``).

Donation discipline lives HERE, not in user code. ``insert`` (and every
engine's ``insert_batch``) donates the old buffers for a zero-copy
update and then marks the input value *consumed*: touching it again
raises :class:`StaleIndexError` with a clear message instead of the
backend-dependent deleted-buffer crash the PR-3 API had ("never reuse a
pre-insert engine" used to be a docstring footnote; now it is enforced).
Pass ``donate=False`` to trade one buffer copy for a reusable input.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import idl as idl_mod


class StaleIndexError(RuntimeError):
    """A donated (consumed) index value was used again."""


_STALE_MSG = (
    "this {what} was consumed by an insert: its storage buffer was donated "
    "to the updated value, so only the *returned* index may be used "
    "(linear-use style). Keep the result of insert()/insert_batch(), or "
    "pass donate=False to keep the input alive at the cost of one copy."
)


def mark_consumed(obj) -> None:
    """Flag a (frozen) index value as donated-away. Idempotent."""
    object.__setattr__(obj, "_consumed", True)


def ensure_live(obj, *arrays, what: str = "index value") -> None:
    """Raise :class:`StaleIndexError` if ``obj`` was consumed by an insert.

    Two layers: the explicit consumed flag (deterministic on every
    backend — XLA:CPU ignores donation, so the buffers themselves stay
    silently valid there) and the buffers' own ``is_deleted`` state (catches
    aliased values on backends that really donate). Tracers are skipped:
    inside a jit the linearity question is the caller's.
    """
    if getattr(obj, "_consumed", False):
        raise StaleIndexError(_STALE_MSG.format(what=what))
    for a in arrays:
        if isinstance(a, jax.core.Tracer) or not isinstance(a, jax.Array):
            continue
        try:
            deleted = a.is_deleted()
        except Exception:  # noqa: BLE001 - defensive: liveness is advisory
            deleted = False
        if deleted:
            raise StaleIndexError(_STALE_MSG.format(what=what))


# ---------------------------------------------------------------------------
# The state pytree.
# ---------------------------------------------------------------------------

ENGINES = ("bloom", "cobs", "rambo", "bitsliced")


@dataclasses.dataclass(frozen=True)
class StateMeta:
    """Hashable static half of an :class:`IndexState` (pytree aux data).

    ``cfgs`` has one entry per words leaf (COBS: one per size group; every
    other engine: exactly one). Engine-specific geometry is ``None`` where
    it does not apply.
    """

    engine: str                                   # one of ENGINES
    scheme: str
    cfgs: Tuple[idl_mod.IDLConfig, ...]
    n_files: Optional[int] = None                 # cobs / rambo / bitsliced
    k: Optional[int] = None                       # cobs top-level kmer size
    group_file_ids: Optional[Tuple[Tuple[int, ...], ...]] = None   # cobs
    n_buckets: Optional[int] = None               # rambo B
    n_rep: Optional[int] = None                   # rambo R

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(
                f"unknown engine kind {self.engine!r} (want one of {ENGINES})"
            )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IndexState:
    """Pytree-native index storage: word matrices as leaves, meta as aux."""

    words: Tuple[jax.Array, ...]
    meta: StateMeta

    def tree_flatten(self):
        return tuple(self.words), self.meta

    @classmethod
    def tree_unflatten(cls, meta, children):
        return cls(words=tuple(children), meta=meta)

    @property
    def nbytes(self) -> int:
        return sum(int(w.size) * 4 for w in self.words)

    def block_until_ready(self) -> "IndexState":
        for w in self.words:
            jax.block_until_ready(w)
        return self


def kmer_size(meta: StateMeta) -> int:
    """The kmer size every read/query against this state is cut into."""
    return int(meta.k if meta.k is not None else meta.cfgs[0].k)


# ---------------------------------------------------------------------------
# Engine <-> state conversion.
# ---------------------------------------------------------------------------

def from_engine(index) -> IndexState:
    """Extract the :class:`IndexState` behind any engine value."""
    from repro.index import engines

    if isinstance(index, IndexState):
        return index
    if isinstance(index, engines.PackedBloomIndex):
        ensure_live(index, index.words, what="engine")
        return IndexState(
            words=(index.words,),
            meta=StateMeta(engine="bloom", scheme=index.scheme,
                           cfgs=(index.cfg,)),
        )
    if isinstance(index, engines.CobsIndex):
        ensure_live(index, *(g.words for g in index.groups), what="engine")
        return IndexState(
            words=tuple(g.words for g in index.groups),
            meta=StateMeta(
                engine="cobs", scheme=index.scheme,
                cfgs=tuple(g.cfg for g in index.groups),
                n_files=index.n_files, k=index.k,
                group_file_ids=tuple(g.file_ids for g in index.groups),
            ),
        )
    if isinstance(index, engines.RamboIndex):
        ensure_live(index, index.words, what="engine")
        return IndexState(
            words=(index.words,),
            meta=StateMeta(engine="rambo", scheme=index.scheme,
                           cfgs=(index.cfg,), n_files=index.n_files,
                           n_buckets=index.n_buckets, n_rep=index.n_rep),
        )
    if isinstance(index, engines.BitSlicedIndex):
        ensure_live(index, index.words, what="engine")
        return IndexState(
            words=(index.words,),
            meta=StateMeta(engine="bitsliced", scheme=index.scheme,
                           cfgs=(index.cfg,), n_files=index.n_files),
        )
    raise TypeError(f"not a GeneIndex engine or IndexState: {type(index)!r}")


def to_engine(state: IndexState):
    """Rebuild the engine view a state was extracted from (loss-free)."""
    from repro.index import engines

    ensure_live(state, *state.words, what="IndexState")
    meta = state.meta
    if meta.engine == "bloom":
        return engines.PackedBloomIndex(
            cfg=meta.cfgs[0], scheme=meta.scheme, words=state.words[0])
    if meta.engine == "cobs":
        groups = tuple(
            engines.CobsGroupState(cfg=cfg, file_ids=fids, words=w)
            for cfg, fids, w in zip(meta.cfgs, meta.group_file_ids,
                                    state.words)
        )
        return engines.CobsIndex(groups=groups, scheme=meta.scheme,
                                 n_files=meta.n_files, k=meta.k)
    if meta.engine == "rambo":
        return engines.RamboIndex(
            cfg=meta.cfgs[0], scheme=meta.scheme, n_files=meta.n_files,
            n_buckets=meta.n_buckets, n_rep=meta.n_rep,
            words=state.words[0])
    if meta.engine == "bitsliced":
        return engines.BitSlicedIndex(
            cfg=meta.cfgs[0], scheme=meta.scheme, n_files=meta.n_files,
            words=state.words[0])
    raise ValueError(f"unknown engine kind {meta.engine!r}")


# ---------------------------------------------------------------------------
# The pure functional API.
# ---------------------------------------------------------------------------

def insert(
    state: IndexState,
    reads: jax.Array,
    file_ids=None,
    *,
    donate: bool = True,
    **kw,
) -> IndexState:
    """Pure insert: returns the updated state; consumes ``state``.

    With ``donate=True`` (default) the input state's buffers are donated
    to the result and ``state`` is marked consumed — further use raises
    :class:`StaleIndexError`. With ``donate=False`` the input stays live
    (one extra buffer copy). ``kw`` passes through to the shared ingest
    layer (``backend`` in {"jnp", "idl_insert", "sharded"}, ``mesh``,
    ``window_min``, ...).
    """
    eng = to_engine(state)
    new_eng = eng.insert_batch(reads, file_ids, donate=donate, **kw)
    if donate:
        mark_consumed(state)
    return from_engine(new_eng)


def query(state: IndexState, reads: jax.Array, *, backend: str = "jnp",
          **kw) -> jax.Array:
    """Pure per-kmer membership query (engine-shaped output)."""
    return to_engine(state).query_batch(reads, backend=backend, **kw)


def msmt(state: IndexState, reads: jax.Array, theta: float = 1.0,
         **kw) -> jax.Array:
    """Pure Multiple-Set Membership Test at coverage threshold ``theta``."""
    return to_engine(state).msmt(reads, theta=theta, **kw)

"""Obs export: one snapshot shape, one fleet merge, one dump format.

``snapshot()`` bundles the process's metrics snapshot and finished trace
records into a single plain dict — the payload a fabric worker or shard
member returns for a ``stats`` IPC request. ``merge()`` folds any number
of those (gateway + workers, router + shards) into one fleet view:
counters/histograms sum via :func:`repro.obs.metrics.merge`, trace records
concatenate (span ids are pid-scoped so stitching needs no renumbering).

``dump()`` writes the fleet view to disk as two artifacts next to each
other: ``PATH`` (metrics + traces, JSON) and ``PATH`` with a ``.chrome``
suffix inserted (Chrome ``trace_event`` file for chrome://tracing) — the
``launch/serve.py --obs-dump`` and CI-artifact format.

``cache_stats_view()`` derives the classic membership-cache stats dict
(hits / misses / lookups / hit_rate / entries / capacity / evictions /
invalidations) from a (possibly merged) snapshot's ``kmer_cache.*``
series — the registry-backed replacement for each tier hand-merging
per-cache dicts.
"""

from __future__ import annotations

import json
import pathlib
from typing import Iterable, List, Optional

from repro.obs import metrics as metrics_mod
from repro.obs import trace as trace_mod


def snapshot(registry: Optional[metrics_mod.Registry] = None,
             tracer: Optional[trace_mod.Tracer] = None) -> dict:
    """This process's full obs state: ``{"metrics": <registry snapshot>,
    "spans": [finished records...]}``. Plain data — safe to pickle over
    IPC or json.dump to disk."""
    reg = registry if registry is not None else metrics_mod.DEFAULT
    trc = tracer if tracer is not None else trace_mod.DEFAULT
    return {"metrics": reg.snapshot(), "spans": trc.records()}


def merge(snapshots: Iterable[dict]) -> dict:
    """Fleet merge of :func:`snapshot` dicts: metrics fold through
    :func:`repro.obs.metrics.merge`, span records concatenate in time
    order."""
    snaps = [s for s in snapshots if s]
    spans: List[dict] = []
    for s in snaps:
        spans.extend(s.get("spans", ()))
    spans.sort(key=lambda r: r.get("t0", 0.0))
    return {"metrics": metrics_mod.merge(s.get("metrics", {})
                                         for s in snaps),
            "spans": spans}


def traces_of(snap: dict) -> dict:
    """Group a (merged) snapshot's span records per trace id."""
    traces: dict = {}
    for rec in snap.get("spans", ()):
        traces.setdefault(rec["trace"], []).append(rec)
    for recs in traces.values():
        recs.sort(key=lambda r: r["t0"])
    return traces


def chrome_events(snap: dict) -> dict:
    """Chrome ``trace_event`` JSON for a (merged) snapshot's spans."""
    events = [{"name": rec["name"], "ph": "X", "cat": rec["status"],
               "ts": rec["t0"] * 1e6, "dur": rec["dur"] * 1e6,
               "pid": rec["pid"], "tid": rec["trace"],
               "args": {"span": rec["span"], "parent": rec["parent"],
                        **rec.get("attrs", {})}}
              for rec in snap.get("spans", ())]
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump(snap: dict, path: str) -> List[str]:
    """Write a (merged) snapshot to ``path`` (metrics + traces, JSON) and
    a sibling ``<stem>.chrome.json`` Chrome trace. Returns the written
    paths."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    doc = {"metrics": snap.get("metrics", {}),
           "traces": traces_of(snap)}
    p.write_text(json.dumps(doc, indent=2, default=float) + "\n")
    chrome = p.with_suffix(".chrome.json")
    chrome.write_text(json.dumps(chrome_events(snap), default=float) + "\n")
    return [str(p), str(chrome)]


def cache_stats_view(snap: dict) -> dict:
    """Membership-cache stats dict from a snapshot's ``kmer_cache.*``
    series — counters sum across every cache instance / process in the
    snapshot, so one helper serves the single-service, router, fabric and
    scatter tiers alike (shape-compatible with the historical
    ``KmerCache.stats()`` / ``merge_cache_stats()`` dicts)."""
    m = snap.get("metrics", snap)   # accept a bare metrics snapshot too
    hits = metrics_mod.counter_total(m, "kmer_cache.hits")
    misses = metrics_mod.counter_total(m, "kmer_cache.misses")
    lookups = hits + misses
    return {
        "hits": int(hits),
        "misses": int(misses),
        "lookups": int(lookups),
        "hit_rate": (hits / lookups) if lookups else 0.0,
        "entries": int(metrics_mod.gauge_total(m, "kmer_cache.entries")),
        "capacity": int(metrics_mod.gauge_total(m, "kmer_cache.capacity")),
        "evictions": int(metrics_mod.counter_total(
            m, "kmer_cache.evictions")),
        "invalidations": int(metrics_mod.counter_total(
            m, "kmer_cache.invalidations")),
    }

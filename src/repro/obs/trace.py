"""Per-request tracing: spans, cross-process trace context, ring buffer.

A trace id is minted once at admission (service/scheduler submit, fabric
gateway, scatter router) and follows the request everywhere — including
across process boundaries: the gateway puts ``(trace_id, parent_span_id)``
on the :class:`repro.serving.ipc.Request` frame, the worker opens child
spans under that parent, and the worker's finished spans ride back in the
obs snapshot so the gateway can stitch one tree out of many processes.

Span ids are ``"<pid hex>.<seq hex>"`` strings, so ids minted in different
processes can never collide and a stitched tree needs no renumbering.
Timing uses the monotonic clock for durations (immune to wall-clock
steps); each record also carries an epoch-anchored start (monotonic offset
re-based once at import) so spans from one host line up on a shared
timeline in the Chrome viewer.

Finished spans are plain dicts in a bounded ring (:class:`Tracer`), never
an unbounded log. Two export shapes: ``export()`` groups records by trace
id (JSON), ``export_chrome()`` emits ``trace_event`` "X" (complete)
events loadable by chrome://tracing / Perfetto.

Hot-path discipline: the batch pipeline does not build Span objects per
request mid-flight — it stamps monotonic times it mostly already takes,
and emits finished records in one pass at finalize (:meth:`Tracer.emit`).
An open :class:`Span` object is only held where someone must be able to
*close it with an error later* (gateway-side dispatch spans, so a worker
death closes them instead of leaking them).
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

# Trace context as it rides an IPC frame: (trace_id, parent_span_id).
TraceContext = Tuple[str, str]

# Re-based once: epoch seconds at monotonic zero, so monotonic stamps
# taken anywhere in this process convert to a shared wall timeline.
_EPOCH0 = time.time() - time.monotonic()

# Process-wide id sequence shared by every Tracer instance.
_SEQ = itertools.count(1)


def mono_to_epoch(t_mono: float) -> float:
    return _EPOCH0 + t_mono


class Span:
    """An OPEN span. Created via :meth:`Tracer.start`; finished with
    :meth:`end` (ok) or :meth:`end` with ``status='error'``. The tracer
    tracks open spans so an owner (gateway) can error-close everything a
    dead worker left behind."""

    __slots__ = ("tracer", "name", "trace_id", "span_id", "parent_id",
                 "attrs", "t0", "_done")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 span_id: str, parent_id: Optional[str],
                 attrs: Optional[dict]):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self.t0 = time.monotonic()
        self._done = False

    def context(self) -> TraceContext:
        """The ``(trace_id, span_id)`` pair a child — possibly in another
        process — opens under."""
        return (self.trace_id, self.span_id)

    def end(self, status: str = "ok", **attrs: object) -> None:
        if self._done:                     # idempotent: late reply after a
            return                         # death-closure must not re-emit
        self._done = True
        t1 = time.monotonic()
        if attrs:
            merged = dict(self.attrs) if self.attrs else {}
            merged.update(attrs)
        else:
            merged = self.attrs
        self.tracer._finish(self, self.t0, t1 - self.t0, status, merged)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.end("error" if exc_type is not None else "ok")


# Ring-internal record layout. The hot path appends TUPLES (one small
# allocation instead of a dict build per span); ``records()`` renders
# them as the public dict shape at export time, off the hot path.
_TRACE, _SPAN, _PARENT, _NAME, _PID, _T0, _DUR, _STATUS, _ATTRS = range(9)


def _to_dict(rec: tuple) -> dict:
    d = {"trace": rec[_TRACE], "span": rec[_SPAN], "parent": rec[_PARENT],
         "name": rec[_NAME], "pid": rec[_PID], "t0": rec[_T0],
         "dur": rec[_DUR], "status": rec[_STATUS]}
    if rec[_ATTRS]:
        d["attrs"] = dict(rec[_ATTRS])
    return d


class Tracer:
    """Bounded ring of finished span records + the open-span table.

    Records are plain dicts::

        {"trace": id, "span": id, "parent": id|None, "name": str,
         "pid": int, "t0": epoch_s, "dur": s, "status": "ok"|"error",
         "attrs": {...}}   # attrs omitted when empty

    (Internally the ring holds tuples — see ``_to_dict`` — so the
    per-span hot-path cost is one tuple literal + one deque append;
    everything exported is the dict shape above.)
    """

    def __init__(self, capacity: int = 8192):
        self.enabled = True
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._open: Dict[str, Span] = {}
        # the seq counter is process-global, not per-instance: ids stay
        # unique even when tests build several private tracers in one
        # process
        self._seq = _SEQ
        self._pid = os.getpid()
        self._prefix = "%x." % self._pid    # span-id prefix, formatted once

    # -- ids -------------------------------------------------------------
    def mint_trace(self) -> str:
        """New trace id, unique across processes (pid + per-process seq)."""
        return "t" + self._prefix + "%x" % next(self._seq)

    def _mint_span(self) -> str:
        return self._prefix + "%x" % next(self._seq)

    # -- open spans ------------------------------------------------------
    def start(self, name: str, trace: Optional[TraceContext] = None,
              **attrs: object) -> Span:
        """Open a span. ``trace=None`` mints a fresh trace id (admission);
        otherwise the span is a child of ``trace = (trace_id, parent)`` —
        which may have been minted in another process."""
        if trace is None:
            trace_id, parent = self.mint_trace(), None
        else:
            trace_id, parent = trace
        span = Span(self, name, trace_id, self._mint_span(), parent,
                    attrs or None)
        if self.enabled:
            with self._lock:
                self._open[span.span_id] = span
        return span

    def _finish(self, span: Span, t0_mono: float, dur: float, status: str,
                attrs: Optional[dict]) -> None:
        with self._lock:
            self._open.pop(span.span_id, None)
        if not self.enabled:
            return
        self._ring.append((span.trace_id, span.span_id, span.parent_id,
                           span.name, self._pid, _EPOCH0 + t0_mono, dur,
                           status, dict(attrs) if attrs else None))

    def close_open_spans(self, status: str = "error",
                         **attrs: object) -> int:
        """Error-close every still-open span (gateway shutdown, or a
        worker whose process died taking its in-flight work). Returns how
        many were closed."""
        with self._lock:
            orphans = list(self._open.values())
        for span in orphans:
            span.end(status, **attrs)
        return len(orphans)

    # -- finished-record fast path --------------------------------------
    def emit(self, name: str, trace_id: str, parent: Optional[str],
             t0_mono: float, t1_mono: float, status: str = "ok",
             attrs: Optional[dict] = None) -> Optional[str]:
        """Append an already-timed span in one step — the batch pipeline
        stamps monotonic times as it flows and emits the whole
        queue-wait → assemble → execute → finalize chain at finalize,
        keeping Span bookkeeping off the submit hot path. Returns the new
        span id (so siblings can parent under it), or None when tracing
        is disabled."""
        if not self.enabled:
            return None
        span_id = self._mint_span()
        self._ring.append((trace_id, span_id, parent, name, self._pid,
                           _EPOCH0 + t0_mono, t1_mono - t0_mono, status,
                           attrs or None))
        return span_id

    def emit_chain(self, trace_id: str, parent: Optional[str],
                   root_name: str, t_root0: float, t_root1: float,
                   children, status: str = "ok",
                   root_attrs: Optional[dict] = None) -> Optional[str]:
        """Emit a root span plus already-timed children in ONE call — the
        per-request chain the batch pipeline produces at finalize
        (request + queue_wait/assemble/execute/finalize). ``children`` is
        a sequence of ``(name, t0_mono, t1_mono)``. Everything is local
        variables and tuple literals: per-request tracing costs a couple
        of microseconds instead of five function-call round trips each
        building a dict. Returns the root span id, or None when
        disabled."""
        if not self.enabled:
            return None
        seq, prefix, pid = self._seq, self._prefix, self._pid
        append = self._ring.append
        root = prefix + "%x" % next(seq)
        append((trace_id, root, parent, root_name, pid,
                _EPOCH0 + t_root0, t_root1 - t_root0, status,
                root_attrs or None))
        for name, ta, tb in children:
            append((trace_id, prefix + "%x" % next(seq), root, name, pid,
                    _EPOCH0 + ta, tb - ta, status, None))
        return root

    def emit_request_chains(self, entries, q_end: float, stages,
                            t_done: float, status: str = "ok",
                            shared_attrs: Optional[dict] = None) -> None:
        """Batched :meth:`emit_chain` for one finalized batch: every entry
        gets a root ``request`` span ending at ``t_done`` with a private
        ``queue_wait`` child (admission → ``q_end``) plus the batch-shared
        ``stages`` children (``(name, t0_mono, t1_mono)`` with identical
        times for the whole batch). ``entries`` is ``[(trace_id, parent,
        t_enq_mono, rid), ...]``. The batch-invariant work — epoch
        rebasing of the shared stage times, attribute loads, the shared
        attrs template — is hoisted out of the per-request loop, and each
        request mints ONE sequence id: its children derive their span ids
        from the root (``<root>.q``, ``<root>.0``...), which is unique by
        construction and skips five format/concat rounds per request.
        This is why the batch pipeline calls this instead of per-request
        :meth:`emit_chain`."""
        if not self.enabled:
            return
        seq, prefix, pid = self._seq, self._prefix, self._pid
        append = self._ring.append
        e0 = _EPOCH0
        shared = [(name, ".%d" % j, e0 + ta, tb - ta)
                  for j, (name, ta, tb) in enumerate(stages)]
        base = tuple(shared_attrs.items()) if shared_attrs else ()
        for trace_id, parent, t_enq, rid in entries:
            root = prefix + "%x" % next(seq)
            append((trace_id, root, parent, "request", pid, e0 + t_enq,
                    t_done - t_enq, status, base + (("rid", rid),)))
            append((trace_id, root + ".q", root, "queue_wait",
                    pid, e0 + t_enq, q_end - t_enq, status, None))
            for name, sfx, ta_e, dur in shared:
                append((trace_id, root + sfx, root, name,
                        pid, ta_e, dur, status, None))

    def ingest(self, records: List[dict]) -> None:
        """Fold finished records from ANOTHER tracer (a worker's snapshot,
        shipped over IPC) into this ring — the stitching half of
        cross-process tracing. Records already carry their origin pid."""
        append = self._ring.append
        for r in records:
            append((r["trace"], r["span"], r["parent"], r["name"],
                    r["pid"], r["t0"], r["dur"], r["status"],
                    r.get("attrs")))

    # -- export ----------------------------------------------------------
    def records(self) -> List[dict]:
        """Finished records as public dicts, oldest first (a copy)."""
        return [_to_dict(rec) for rec in self._ring]

    def export(self) -> dict:
        """JSON shape: records grouped per trace id, each trace's spans
        sorted by start time."""
        traces: Dict[str, List[dict]] = {}
        for rec in self.records():
            traces.setdefault(rec["trace"], []).append(rec)
        for spans in traces.values():
            spans.sort(key=lambda r: r["t0"])
        return {"pid": self._pid, "n_spans": sum(map(len, traces.values())),
                "traces": traces}

    def export_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON (load in chrome://tracing or
        Perfetto): one "X" complete event per span, ts/dur in µs, pid =
        origin process, tid = trace id (one row per request)."""
        events = []
        for rec in self.records():
            ev = {"name": rec["name"], "ph": "X", "cat": rec["status"],
                  "ts": rec["t0"] * 1e6, "dur": rec["dur"] * 1e6,
                  "pid": rec["pid"], "tid": rec["trace"],
                  "args": {"span": rec["span"],
                           "parent": rec["parent"],
                           **rec.get("attrs", {})}}
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def clear(self) -> None:
        self._ring.clear()


# Process-local default tracer, same pattern as metrics.DEFAULT.
DEFAULT = Tracer()


def tracer() -> Tracer:
    return DEFAULT


def set_enabled(enabled: bool) -> None:
    DEFAULT.enabled = bool(enabled)

"""Process-local metrics registry: counters, gauges, log2 histograms.

One registry per process (module-level :data:`DEFAULT`), threaded through
every serving tier. Instruments are *pre-bound handles*: a tier calls
``counter("serving.requests", tier="scheduler")`` once at construction and
keeps the returned handle; the hot path then calls ``handle.inc(n)`` which
touches no dict, formats no label string, and allocates nothing — the only
per-event work is one lock acquire and one add. Histograms use fixed log2
buckets (``bucket = bit_length(int(value))``, clamped to
:data:`N_BUCKETS`), so observing a latency is an index increment into a
pre-allocated list.

``snapshot()`` renders the whole registry as a plain nested dict (JSON- and
pickle-clean) and ``merge()`` folds any number of snapshots from other
processes into one — the single cross-process aggregation path used by the
fabric gateway and the scatter router (replacing their per-tier ad-hoc
dict merging).

Labels follow one vocabulary across the stack: ``tier`` (service /
scheduler / router / fabric / scatter), ``engine``, ``scheme``, and
``replica`` / ``shard`` / ``worker`` for fan-out tiers. Extra labels are
allowed; they are sorted into a canonical ``k=v,k2=v2`` string at bind
time, never on the hot path.

Disabling (``set_enabled(False)``) turns every already-bound handle into a
cheap no-op (one attribute load + branch per event) — used by the obs
overhead bench to time obs-off serving without rebuilding the stack.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

N_BUCKETS = 64          # log2 buckets: value v lands in int(v).bit_length()


def _label_key(labels: Mapping[str, object]) -> str:
    """Canonical, sorted ``k=v,k2=v2`` string ('' for unlabelled)."""
    if not labels:
        return ""
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


def parse_label_key(key: str) -> Dict[str, str]:
    """Inverse of the label key: ``'a=1,b=x'`` -> ``{'a': '1', 'b': 'x'}``."""
    if not key:
        return {}
    return dict(part.split("=", 1) for part in key.split(","))


class Counter:
    """Monotonic counter handle. ``inc`` is the zero-allocation hot path."""

    __slots__ = ("_registry", "_lock", "_value")

    def __init__(self, registry: "Registry"):
        self._registry = registry
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge:
    """Last-write-wins gauge handle (entries, occupancy, fleet size...)."""

    __slots__ = ("_registry", "_value")

    def __init__(self, registry: "Registry"):
        self._registry = registry
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._registry.enabled:
            return
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def _reset(self) -> None:
        self._value = 0.0


class Histogram:
    """Fixed-bucket log2 histogram: 64 pre-allocated buckets, no per-event
    allocation. Bucket ``i`` counts values with ``int(v).bit_length() == i``
    (i.e. ``2^(i-1) <= v < 2^i``; bucket 0 holds v < 1), clamped at the
    top. Tracks count / sum / min / max alongside the buckets."""

    __slots__ = ("_registry", "_lock", "buckets", "count", "sum",
                 "min", "max")

    def __init__(self, registry: "Registry"):
        self._registry = registry
        self._lock = threading.Lock()
        self.buckets = [0] * N_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        if not self._registry.enabled:
            return
        i = int(v).bit_length()
        if i >= N_BUCKETS:
            i = N_BUCKETS - 1
        with self._lock:
            self.buckets[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def observe_array(self, values) -> None:
        """Bulk observe a numpy array of non-negative values in one pass —
        per-batch recording (e.g. every run length of a probe plan)
        without a per-element Python call. Bit-identical to a loop of
        scalar ``observe`` calls on either path below."""
        if not self._registry.enabled:
            return
        v = np.asarray(values)
        if v.size == 0:
            return
        if v.dtype.kind in "iu":
            # small-range int fast path (run lengths, probe counts...):
            # bincount the VALUES, then fold the tiny value-count vector
            # through a bit_length table — every per-element pass after
            # the bincount operates on <= hi+1 entries, not v.size
            lo, hi = int(v.min()), int(v.max())
            if lo >= 0 and hi < 4096:
                vals = np.arange(hi + 1, dtype=np.float64)
                counts_v = np.bincount(v.reshape(-1), minlength=hi + 1)
                exps_tab = np.frexp(vals)[1]        # == bit_length per value
                counts = np.bincount(exps_tab, weights=counts_v,
                                     minlength=N_BUCKETS)
                total = float(np.dot(counts_v, vals))
                with self._lock:
                    for i in np.flatnonzero(counts):
                        self.buckets[i] += int(counts[i])
                    self.count += int(v.size)
                    self.sum += total
                    if lo < self.min:
                        self.min = lo
                    if hi > self.max:
                        self.max = hi
                return
        vf = v.astype(np.float64, copy=False)
        # frexp exponent == floor(log2(v)) + 1 == int(v).bit_length() for
        # v >= 1; clipping to 0 folds v < 1 into bucket 0 — identical
        # binning to the scalar path, in one C pass instead of a
        # where/floor/log2 chain
        exps = np.clip(np.frexp(vf)[1], 0, N_BUCKETS - 1)
        counts = np.bincount(exps, minlength=N_BUCKETS)
        lo, hi, total = float(vf.min()), float(vf.max()), float(vf.sum())
        with self._lock:
            for i in np.flatnonzero(counts):
                self.buckets[i] += int(counts[i])
            self.count += int(v.size)
            self.sum += total
            if lo < self.min:
                self.min = lo
            if hi > self.max:
                self.max = hi

    def _reset(self) -> None:
        with self._lock:
            for i in range(N_BUCKETS):
                self.buckets[i] = 0
            self.count = 0
            self.sum = 0.0
            self.min = float("inf")
            self.max = float("-inf")


class Registry:
    """Thread-safe instrument registry. Binding (``counter`` / ``gauge`` /
    ``histogram``) takes the creation lock and canonicalizes labels once;
    the returned handle is then lock-free to *hold* and cheap to hit.
    Binding the same (name, labels) twice returns the same handle, so
    replicas of one process share a counter series when their labels
    coincide and diverge when a ``replica=``/``shard=`` label splits them.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.enabled = True
        self._counters: Dict[Tuple[str, str], Counter] = {}
        self._gauges: Dict[Tuple[str, str], Gauge] = {}
        self._hists: Dict[Tuple[str, str], Histogram] = {}

    # -- binding ---------------------------------------------------------
    def counter(self, name: str, **labels: object) -> Counter:
        key = (name, _label_key(labels))
        with self._lock:
            h = self._counters.get(key)
            if h is None:
                h = self._counters[key] = Counter(self)
            return h

    def gauge(self, name: str, **labels: object) -> Gauge:
        key = (name, _label_key(labels))
        with self._lock:
            h = self._gauges.get(key)
            if h is None:
                h = self._gauges[key] = Gauge(self)
            return h

    def histogram(self, name: str, **labels: object) -> Histogram:
        key = (name, _label_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram(self)
            return h

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain nested dict of everything the process has recorded:
        ``{"pid", "utc", "counters": {name: {labelkey: value}}, "gauges":
        {...}, "hists": {name: {labelkey: {count, sum, min, max,
        buckets}}}}``. JSON- and pickle-clean; this is the unit the fleet
        merge operates on."""
        with self._lock:
            counters = list(self._counters.items())
            gauges = list(self._gauges.items())
            hists = list(self._hists.items())
        snap: dict = {
            "pid": os.getpid(),
            "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "counters": {}, "gauges": {}, "hists": {},
        }
        for (name, lk), c in counters:
            snap["counters"].setdefault(name, {})[lk] = c.value
        for (name, lk), g in gauges:
            snap["gauges"].setdefault(name, {})[lk] = g.value
        for (name, lk), h in hists:
            with h._lock:
                snap["hists"].setdefault(name, {})[lk] = {
                    "count": h.count,
                    "sum": h.sum,
                    "min": h.min if h.count else 0.0,
                    "max": h.max if h.count else 0.0,
                    "buckets": list(h.buckets),
                }
        return snap

    def reset(self) -> None:
        """Zero every bound instrument (handles stay valid) — test isolation
        and per-stream deltas in the benches."""
        with self._lock:
            instruments = (list(self._counters.values())
                           + list(self._gauges.values())
                           + list(self._hists.values()))
        for h in instruments:
            h._reset()


def merge(snapshots: Iterable[dict]) -> dict:
    """Fold process snapshots into one fleet snapshot: counters and
    histogram buckets/count/sum SUM per (name, labelkey); histogram
    min/max take the extrema; gauges are last-write-wins per (name,
    labelkey) — fan-out tiers keep gauges distinct with ``pid=`` /
    ``worker=`` / ``shard=`` labels so nothing collides. This is the one
    cross-process aggregation path (gateway and scatter router both call
    it)."""
    out: dict = {"pid": os.getpid(),
                 "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
                 "merged_from": 0,
                 "counters": {}, "gauges": {}, "hists": {}}
    for snap in snapshots:
        if not snap:
            continue
        # provenance: leaf snapshots count 1, already-merged ones carry
        # their own process count forward
        prior = int(snap.get("merged_from", 0) or 0)
        out["merged_from"] += prior if prior else 1
        for name, series in snap.get("counters", {}).items():
            dst = out["counters"].setdefault(name, {})
            for lk, v in series.items():
                dst[lk] = dst.get(lk, 0.0) + v
        for name, series in snap.get("gauges", {}).items():
            dst = out["gauges"].setdefault(name, {})
            for lk, v in series.items():
                dst[lk] = v
        for name, series in snap.get("hists", {}).items():
            dst = out["hists"].setdefault(name, {})
            for lk, h in series.items():
                cur = dst.get(lk)
                if cur is None:
                    dst[lk] = {"count": h["count"], "sum": h["sum"],
                               "min": h["min"], "max": h["max"],
                               "buckets": list(h["buckets"])}
                else:
                    cur["count"] += h["count"]
                    cur["sum"] += h["sum"]
                    if h["count"]:
                        cur["min"] = (min(cur["min"], h["min"])
                                      if cur["count"] != h["count"]
                                      else h["min"])
                        cur["max"] = max(cur["max"], h["max"])
                    for i, b in enumerate(h["buckets"]):
                        cur["buckets"][i] += b
    return out


def counter_total(snapshot: dict, name: str,
                  where: Optional[Mapping[str, str]] = None) -> float:
    """Sum a counter across every label series in a snapshot, optionally
    filtered (``where={"scheme": "idl"}`` keeps only series whose parsed
    labels contain those pairs). The standard way views roll a fleet
    snapshot up to one number."""
    total = 0.0
    for lk, v in snapshot.get("counters", {}).get(name, {}).items():
        if where:
            labels = parse_label_key(lk)
            if any(labels.get(k) != str(w) for k, w in where.items()):
                continue
        total += v
    return total


def gauge_total(snapshot: dict, name: str,
                where: Optional[Mapping[str, str]] = None) -> float:
    """Sum a gauge across label series (entries across caches, etc.)."""
    total = 0.0
    for lk, v in snapshot.get("gauges", {}).get(name, {}).items():
        if where:
            labels = parse_label_key(lk)
            if any(labels.get(k) != str(w) for k, w in where.items()):
                continue
        total += v
    return total


# The process-local default registry: every serving tier binds against
# this unless handed an explicit registry (tests build private ones).
DEFAULT = Registry()


def registry() -> Registry:
    return DEFAULT


def set_enabled(enabled: bool) -> None:
    """Flip the default registry's master switch. Already-bound handles
    see it immediately (per-event branch), so the obs overhead bench can
    compare on/off without reconstructing the serving stack."""
    DEFAULT.enabled = bool(enabled)


def reset() -> None:
    DEFAULT.reset()

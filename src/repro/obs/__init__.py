"""Observability plane: metrics registry, request tracing, fleet export.

Three modules threaded through every serving tier:

- :mod:`repro.obs.metrics` — process-local thread-safe registry
  (counters / gauges / fixed-bucket log2 histograms) with pre-bound
  handles so the hot path allocates nothing; ``snapshot()`` → plain dict,
  ``merge()`` for cross-process aggregation.
- :mod:`repro.obs.trace` — per-request spans minted at admission, riding
  IPC frames across process boundaries, finished records in a bounded
  ring, exported as JSON or Chrome ``trace_event``.
- :mod:`repro.obs.export` — the one snapshot/merge/dump path shared by
  the fabric gateway, scatter router and ``launch/serve.py --obs-dump``,
  plus registry-backed views (``cache_stats_view``) that replace the
  per-tier stats-dict merging.

``set_enabled(False)`` flips both metrics and tracing to cheap no-ops —
the obs overhead bench's off-switch (contract: obs-on is bit-identical to
obs-off and within 5% of its throughput; ``BENCH_serve.json:obs_overhead``
records the measurement).
"""

from repro.obs import export, metrics, trace
from repro.obs.export import cache_stats_view, chrome_events, dump, \
    snapshot, traces_of
from repro.obs.metrics import Counter, Gauge, Histogram, Registry, \
    counter_total, gauge_total
from repro.obs.trace import Span, TraceContext, Tracer


def set_enabled(enabled: bool) -> None:
    """Master switch for the process-local default registry + tracer."""
    metrics.set_enabled(enabled)
    trace.set_enabled(enabled)


def reset() -> None:
    """Zero the default registry and clear the default tracer's ring —
    test isolation and per-stream deltas in the benches."""
    metrics.reset()
    trace.DEFAULT.clear()
    trace.DEFAULT.close_open_spans(status="error", error="obs_reset")


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "Span",
    "TraceContext",
    "Tracer",
    "cache_stats_view",
    "chrome_events",
    "counter_total",
    "dump",
    "export",
    "gauge_total",
    "metrics",
    "reset",
    "set_enabled",
    "snapshot",
    "trace",
    "traces_of",
]

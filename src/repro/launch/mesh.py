"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """All locally-visible devices on one 'data' axis (examples/train.py)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))

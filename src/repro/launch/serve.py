"""Serving launcher: ``python -m repro.launch.serve --arch idl-genesearch``.

Builds a gene-search index over a synthetic archive and serves batched MSMT
queries — the runnable counterpart of the serve_step the dry-run lowers.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import genome
from repro.serving import genesearch as gs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="idl-genesearch")
    ap.add_argument("--files", type=int, default=32)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--requests", type=int, default=5)
    args = ap.parse_args()

    spec = configs.get(args.arch)
    if spec.family != "genesearch":
        raise SystemExit("serve launcher currently drives idl-genesearch; "
                         "LM decode is exercised via the dry-run cells")
    cfg = spec.make_smoke_config()
    import dataclasses
    args.files = max(32, -(-args.files // 32) * 32)  # bit-sliced: 32/word
    cfg = dataclasses.replace(cfg, n_files=args.files)

    archive = genome.synth_archive(n_files=args.files, genome_len=2_000,
                                   seed=11)
    index = gs.empty_index(cfg)
    for f in archive:
        index = gs.insert_read(index, cfg, f.file_id, jnp.asarray(f.genome))
    print(f"index: {args.files} files, {index.nbytes / 1e6:.1f} MB")

    serve = jax.jit(lambda i, q: gs.serve_step(i, q, cfg))
    rng = np.random.default_rng(0)
    lat = []
    correct = total = 0
    for r in range(args.requests):
        fids = rng.integers(0, args.files, size=args.batch)
        reads = np.stack([
            archive[int(f)].reads(cfg.read_len, 1)[0] for f in fids])
        t0 = time.perf_counter()
        out = serve(index, jnp.asarray(reads))
        out.block_until_ready()
        lat.append(time.perf_counter() - t0)
        for i, fid in enumerate(fids):
            ids = gs.match_file_ids(np.asarray(out[i]))
            correct += int(int(fid) in ids)
            total += 1
    print(f"recall {correct}/{total}; "
          f"p50 latency {1e3 * float(np.median(lat)):.1f} ms "
          f"(batch={args.batch})")


if __name__ == "__main__":
    main()

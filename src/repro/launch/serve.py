"""Serving launcher: ``python -m repro.launch.serve --arch idl-genesearch``.

Builds a gene-search index over a synthetic archive and serves batched MSMT
queries through the v2 engine + service path — the runnable counterpart of
the serve cell the dry-run lowers. ``--procs N`` serves the same traffic
through a :class:`ProcessFabric` instead: the index is snapshotted once
and N worker processes mmap it behind one gateway. ``--shards N``
partitions the index into N shard states, saves the shard-set snapshot,
and serves through a :class:`ScatterGatherRouter` — each shard a worker
process when ``--procs`` is also set — then runs the same recall check
against the merged answers.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

import repro.configs as configs
from repro.data import genome
from repro.index import BitSlicedIndex
from repro.serving import GeneSearchService, ServiceConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="idl-genesearch")
    ap.add_argument("--files", type=int, default=32)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--procs", type=int, default=0, metavar="N",
                    help="serve through a ProcessFabric of N mmap-booted "
                         "worker processes instead of in-process")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="partition the index into N shards and serve "
                         "through a scatter-gather router (with --procs, "
                         "each shard runs in its own worker process)")
    ap.add_argument("--obs-dump", default=None, metavar="PATH",
                    help="after serving, write the merged observability "
                         "snapshot (metrics + traces, JSON) to PATH and "
                         "a Chrome trace_event file next to it")
    args = ap.parse_args()

    spec = configs.get(args.arch)
    if spec.family != "genesearch":
        raise SystemExit("serve launcher currently drives idl-genesearch; "
                         "LM decode is exercised via the dry-run cells")
    cfg = spec.make_smoke_config()
    import dataclasses
    args.files = max(32, -(-args.files // 32) * 32)  # bit-sliced: 32/word
    if args.shards:
        # file shards split on 32-file word columns: one column per shard
        # is the floor
        args.files = max(args.files, 32 * args.shards)
    cfg = dataclasses.replace(cfg, n_files=args.files)

    archive = genome.synth_archive(n_files=args.files, genome_len=2_000,
                                   seed=11)
    eng = BitSlicedIndex.build(cfg.idl_config(), cfg.scheme, cfg.n_files)
    for f in archive:
        eng = eng.insert_batch(np.asarray(f.genome)[None],
                               np.asarray([f.file_id], dtype=np.int32))
    print(f"index: {args.files} files, "
          f"{eng.state.nbytes / 1e6:.1f} MB bit-sliced IndexState")

    svc_cfg = ServiceConfig(theta=cfg.theta, max_batch=args.batch)
    if args.shards:
        from repro.index import shards as shards_mod
        from repro.serving import ScatterConfig, ScatterGatherRouter
        tmp = tempfile.TemporaryDirectory(prefix="serve_shards_")
        spec, parts = shards_mod.partition_state(eng, args.shards)
        shards_mod.save_shard_set(spec, parts, f"{tmp.name}/set")
        router = ScatterGatherRouter(f"{tmp.name}/set", ScatterConfig(
            procs=bool(args.procs), service=svc_cfg))
        mode = ("worker processes" if args.procs
                else "in-process schedulers")
        print(f"shards: {spec.n_shards} shards over the {spec.axis!r} "
              f"axis, served by {mode} (set version "
              f"{router.set_version})")
        search = router.search
    elif args.procs:
        from repro.index import store
        from repro.serving import FabricConfig, ProcessFabric
        tmp = tempfile.TemporaryDirectory(prefix="serve_fabric_")
        snap = store.save(eng, f"{tmp.name}/snap")
        fab = ProcessFabric(snap, FabricConfig(n_workers=args.procs,
                                               service=svc_cfg))
        print(f"fabric: {args.procs} worker processes, pids "
              f"{sorted(fab.worker_pids().values())}")
        search = fab.search
    else:
        svc = GeneSearchService(eng, svc_cfg)
        search = svc.search
    rng = np.random.default_rng(0)
    lat = []
    correct = total = 0
    for r in range(args.requests):
        fids = rng.integers(0, args.files, size=args.batch)
        reads = [np.asarray(archive[int(f)].reads(cfg.read_len, 1)[0])
                 for f in fids]
        t0 = time.perf_counter()
        results = search(reads)
        lat.append(time.perf_counter() - t0)
        for fid, res in zip(fids, results):
            correct += int(int(fid) in res.file_ids)
            total += 1
    print(f"recall {correct}/{total}; "
          f"p50 latency {1e3 * float(np.median(lat)):.1f} ms "
          f"(batch={args.batch})")
    if args.obs_dump:
        from repro.obs import export as obs_export
        if args.shards:
            snap = router.obs_snapshot()   # fleet merge over shard procs
        elif args.procs:
            snap = fab.obs_snapshot()      # fleet merge over workers
        else:
            snap = obs_export.snapshot()   # one process = one registry
        paths = obs_export.dump(snap, args.obs_dump)
        print(f"obs: {len(snap.get('spans', ()))} spans, "
              f"{len(snap['metrics'].get('counters', {}))} counter "
              f"series -> {paths[0]} (+ {paths[1]})")
    if args.shards:
        router.close()
        tmp.cleanup()
    elif args.procs:
        fab.close()
        tmp.cleanup()


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements of this module — jax
locks the device count at first initialization, and the production meshes
need 512 placeholder host devices. Nothing else in the repo sets this flag
(tests and benches see 1 device).

Per cell:
    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...)\
            .lower(state, **input_specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Usage:
    python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all           # every cell, both meshes
                                                  # (subprocess per cell)
Records land in --out (default runs/dryrun/) as one JSON per cell; the
roofline report (benchmarks/roofline_report.py) and EXPERIMENTS.md read them.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax


def _mesh(name: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(name == "multi"))


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             overrides: dict | None = None) -> dict:
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import base as cfg_base, get
    from repro.distributed import sharding as sh
    from repro.roofline import analysis

    # the lowered program must be TPU-lane-compatible: strictly 32-bit.
    # (repro.__init__ enables x64 for the uint64 CPU reference paths only.)
    jax.config.update("jax_enable_x64", False)

    spec = get(arch)
    cfg = spec.make_config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cell = spec.shapes[shape]
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "kind": cell.kind}
    if cell.skip_reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip_reason
        _write(out_dir, rec)
        return rec

    mesh = _mesh(mesh_name)
    chips = mesh.devices.size
    # sequence-parallel residual stream for LM training/prefill (the big
    # activations); decode and the other families keep seq replicated.
    seq_parallel = spec.family == "lm" and cell.meta.get("mode") != "decode"
    rules = sh.ShardingRules(
        mesh=mesh, mapping=sh.default_mapping(mesh, seq_parallel=seq_parallel)
    )

    state = spec.abstract_state(cfg, cell)
    batch = spec.input_specs(cfg, cell)
    state_sh = cfg_base.tree_shardings(
        mesh, state, lambda p, s: spec.state_spec_fn(cfg, p, s))
    batch_sh = cfg_base.tree_shardings(
        mesh, batch, lambda p, s: spec.batch_spec_fn(cfg, p, s))
    fn = spec.step_fn(cfg, cell)

    t0 = time.time()
    with mesh:
        with sh.use_rules(rules):
            lowered = jax.jit(
                fn, in_shardings=(state_sh, batch_sh)
            ).lower(state, batch)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = analysis.memory_stats(compiled)
    print("memory_analysis:", mem)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0))))
    except Exception as e:
        print("cost_analysis failed:", e)

    mf = spec.model_flops_fn(cfg, cell) if spec.model_flops_fn else None
    roof = analysis.from_compiled(arch, shape, mesh_name, chips, compiled,
                                  model_flops=mf)
    rec.update(roof.to_json())
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    _write(out_dir, rec)
    return rec


def _write(out_dir: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def run_all(out_dir: str, meshes: list[str], jobs: int = 2,
            archs: list[str] | None = None, timeout: int = 3600) -> int:
    """Every cell in a fresh subprocess (isolated XLA state/memory)."""
    from repro.configs import all_archs, get

    cells = []
    for arch in (archs or all_archs()):
        for shape, cell in get(arch).cells():
            for mesh_name in meshes:
                cells.append((arch, shape, mesh_name))
    procs: list[tuple] = []
    failures = 0

    def reap(block: bool) -> int:
        nonlocal procs
        fails, alive = 0, []
        for p, meta, t0 in procs:
            if p.poll() is None and not block:
                alive.append((p, meta, t0))
                continue
            try:
                p.wait(timeout=max(1, timeout - (time.time() - t0)))
            except subprocess.TimeoutExpired:
                p.kill()
                print(f"TIMEOUT {meta}")
                fails += 1
                continue
            if p.returncode != 0:
                print(f"FAIL {meta} rc={p.returncode}")
                fails += 1
            else:
                print(f"ok   {meta}")
        procs = alive
        return fails

    for arch, shape, mesh_name in cells:
        done = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(done):
            print(f"skip {arch}/{shape}/{mesh_name} (cached)")
            continue
        while len(procs) >= jobs:
            failures += reap(block=False)
            if len(procs) >= jobs:
                time.sleep(2)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh_name,
               "--out", out_dir]
        p = subprocess.Popen(cmd, stdout=subprocess.DEVNULL,
                             stderr=subprocess.DEVNULL)
        procs.append((p, f"{arch}/{shape}/{mesh_name}", time.time()))
    failures += reap(block=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()

    if args.all:
        fails = run_all(args.out, ["single", "multi"], jobs=args.jobs,
                        archs=args.archs)
        sys.exit(1 if fails else 0)

    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out)
        print(json.dumps({k: v for k, v in rec.items()
                          if k not in ("coll_breakdown", "memory_stats")},
                         indent=1))
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop for any trainable arch at a REDUCED
scale on the local host devices (the full-scale configs are exercised by
the dry-run; this entry point is the runnable driver — same loop, same
checkpoints, same pipelines).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import graph_pipeline, lm_pipeline, recsys_pipeline
from repro.models import equiformer as eq, recsys, transformer as tf
from repro.train import loop, optimizer as opt_mod


def _lm_runner(spec, args):
    cfg = spec.make_smoke_config()
    pipe = lm_pipeline.LMPipeline(lm_pipeline.LMPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        dedup=True, dedup_scheme="idl"))
    params = tf.lm_init(jax.random.PRNGKey(args.seed), cfg)
    loss = lambda p, b: tf.lm_loss(p, b, cfg, loss_chunks=4)
    batch_fn = lambda: {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    return params, loss, batch_fn, pipe


def _gnn_runner(spec, args):
    import dataclasses
    cfg = dataclasses.replace(spec.make_smoke_config(), n_classes=8)
    g = graph_pipeline.synth_graph(512, 4096, n_classes=8, seed=args.seed)
    loader = graph_pipeline.FanoutLoader(g, args.batch, [5, 5], 1024, 8192)
    params = eq.equiformer_init(jax.random.PRNGKey(args.seed), cfg)
    loss = lambda p, b: eq.equiformer_loss(p, b, cfg)
    batch_fn = lambda: {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
    return params, loss, batch_fn, None


def _recsys_runner(spec, args):
    cfg = spec.make_smoke_config()
    gen = recsys_pipeline.SessionGenerator(recsys_pipeline.RecsysSynthConfig(
        n_items=getattr(cfg, "n_items", 1 << 10),
        session_len=getattr(cfg, "seq_len", 12), seed=args.seed))
    name = spec.name
    key = jax.random.PRNGKey(args.seed)
    if name == "sasrec":
        params = recsys.sasrec_init(key, cfg)
        loss = lambda p, b: recsys.sasrec_loss(p, b, cfg)
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            gen.sasrec_batch(args.batch).items()}
    elif name == "fm":
        params = recsys.fm_init(key, cfg)
        loss = lambda p, b: recsys.fm_loss(p, b, cfg)
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            gen.fm_batch(args.batch, cfg.n_sparse,
                                         cfg.vocab_per_field).items()}
    elif name == "two-tower-retrieval":
        params = recsys.twotower_init(key, cfg)
        loss = lambda p, b: recsys.twotower_loss(p, b, cfg)
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            gen.twotower_batch(args.batch).items()}
    else:  # mind
        params = recsys.mind_init(key, cfg)
        loss = lambda p, b: recsys.mind_loss(p, b, cfg)
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            gen.mind_batch(args.batch).items()}
    return params, loss, batch_fn, None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.all_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    spec = configs.get(args.arch)
    if spec.family == "lm":
        params, loss, batch_fn, pipe = _lm_runner(spec, args)
    elif spec.family == "gnn":
        params, loss, batch_fn, pipe = _gnn_runner(spec, args)
    elif spec.family == "recsys":
        params, loss, batch_fn, pipe = _recsys_runner(spec, args)
    else:
        raise SystemExit(f"{args.arch} has no train step (serve-only arch); "
                         f"use repro.launch.serve")

    lcfg = loop.LoopConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 10, 1))
    result = loop.run(
        loss, params, opt_mod.make_optimizer(args.optimizer, args.lr),
        batch_fn, lcfg,
        pipeline_state=pipe.state_dict if pipe else None,
        restore_pipeline=pipe.load_state_dict if pipe else None)
    for h in result.history:
        print(h)
    print(f"done: {args.arch} loss {result.history[0]['loss']:.4f} -> "
          f"{result.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

"""repro — IDL-hash gene-search framework on JAX (multi-pod).

x64 note: packed 31-mers need 62 bits, so the CPU reference path enables
jax_enable_x64. TPU has no native 64-bit integer lanes, so everything that
must lower for the TPU target (kernels, serving, model code) is strictly
32-bit — kmers travel as (hi, lo) uint32 pairs there (see
``repro.core.hashing.hash_pair32`` and DESIGN.md §2). Model code pins dtypes
explicitly, so the flag does not change training numerics.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"

"""Synthetic genome generation + FASTA/FASTQ-ish IO + query poisoning.

The paper evaluates on ENA FASTQ files (offline here), so the data substrate
provides: (a) reproducible synthetic genomes with realistic repeat structure,
(b) read extraction (fixed-length fragments, the unit the paper indexes),
(c) the paper's 1-poisoning query generator ("for each sequence ... sample a
subsequence of length > 31 and poison it by changing one character at a
random location" — §7), and (d) minimal FASTA read/write so examples can
round-trip real files when present.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core import kmers

_BASES = np.frombuffer(b"ACGT", dtype=np.uint8)


def synthesize_genome(
    length: int,
    seed: int = 0,
    repeat_fraction: float = 0.3,
    repeat_unit: int = 500,
) -> np.ndarray:
    """Random genome codes with planted repeats (uint8 in {0..3}).

    Real genomes are highly repetitive; ``repeat_fraction`` of the output is
    tiled from a small library of repeat units so kmer-multiplicity and BF
    fill statistics resemble real data rather than iid noise.
    """
    rng = np.random.default_rng(seed)
    out = rng.integers(0, 4, size=length, dtype=np.uint8)
    n_repeat = int(length * repeat_fraction)
    if n_repeat and length > repeat_unit * 2:
        library = [
            rng.integers(0, 4, size=repeat_unit, dtype=np.uint8) for _ in range(8)
        ]
        placed = 0
        while placed < n_repeat:
            unit = library[rng.integers(0, len(library))]
            start = int(rng.integers(0, length - repeat_unit))
            out[start : start + repeat_unit] = unit
            placed += repeat_unit
    return out


def extract_reads(
    genome: np.ndarray, read_len: int, n_reads: int, seed: int = 1
) -> np.ndarray:
    """(n_reads, read_len) uint8 fragments sampled uniformly (with overlap)."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(genome) - read_len + 1, size=n_reads)
    return np.stack([genome[s : s + read_len] for s in starts])


def window_reads(codes: np.ndarray, read_len: int, k: int) -> np.ndarray:
    """Fixed-length windows of ``codes`` covering every kmer exactly.

    Consecutive windows overlap by ``k - 1`` bases so no boundary kmer is
    lost; the final window is re-anchored to the sequence end (the extra
    overlap re-inserts kmers, which is free — scatter-OR is idempotent).
    Sequences shorter than ``read_len`` come back as one window of their
    own length; sequences shorter than ``k`` (no kmers) as an empty batch.
    This is the chunking unit of the streaming archive builder
    (:func:`repro.index.ingest.build_archive`).
    """
    codes = np.asarray(codes)
    if read_len < k:
        raise ValueError(
            f"read_len={read_len} must be >= k={k} (a window must hold at "
            "least one kmer)")
    n = len(codes)
    if n < k:
        return np.empty((0, n), dtype=codes.dtype)
    if n <= read_len:
        return codes[None, :]
    stride = read_len - (k - 1)
    starts = list(range(0, n - read_len + 1, stride))
    if starts[-1] != n - read_len:
        starts.append(n - read_len)
    return np.stack([codes[s : s + read_len] for s in starts])


def poison_queries(
    reads: np.ndarray, seed: int = 2, n_flips: int = 1
) -> np.ndarray:
    """The paper's 1-poisoning attack: flip ``n_flips`` random bases per read.

    Each query then maximally resembles an inserted sequence while (whp) not
    being a member — the hard negative for FPR measurement.
    """
    rng = np.random.default_rng(seed)
    out = reads.copy()
    n, length = out.shape
    for _ in range(n_flips):
        pos = rng.integers(0, length, size=n)
        delta = rng.integers(1, 4, size=n).astype(np.uint8)  # guaranteed change
        out[np.arange(n), pos] = (out[np.arange(n), pos] + delta) % 4
    return out


@dataclasses.dataclass
class GenomeFile:
    """One 'file' of the archive: a genome plus its reads."""

    file_id: int
    genome: np.ndarray

    def reads(self, read_len: int, n_reads: int) -> np.ndarray:
        return extract_reads(self.genome, read_len, n_reads, seed=100 + self.file_id)

    @property
    def n_kmers(self) -> int:
        return len(self.genome) - 31 + 1


def synth_archive(
    n_files: int, genome_len: int, seed: int = 0
) -> list[GenomeFile]:
    """An archive of distinct genomes (distinct seeds => ~disjoint kmer sets)."""
    return [
        GenomeFile(file_id=i, genome=synthesize_genome(genome_len, seed=seed + 31 * i))
        for i in range(n_files)
    ]


# --------------------------------------------------------------------------
# FASTA round-trip (examples can consume real files when available)
# --------------------------------------------------------------------------

def write_fasta(path: str, records: dict[str, np.ndarray]) -> None:
    with open(path, "w") as f:
        for name, codes in records.items():
            f.write(f">{name}\n")
            s = kmers.decode_bases(codes)
            for i in range(0, len(s), 80):
                f.write(s[i : i + 80] + "\n")


def read_fasta(path: str) -> dict[str, np.ndarray]:
    records: dict[str, list[str]] = {}
    name = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith(">"):
                name = line[1:].split()[0]
                records[name] = []
            elif name is not None:
                records[name].append(line)
    return {
        n: kmers.encode_bases("".join(parts)) for n, parts in records.items()
    }

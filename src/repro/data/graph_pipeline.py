"""Graph data: synthetic graphs shaped like the assigned GNN cells + batching.

Provides the host-side halves of the four equiformer-v2 shapes:
  full_graph_sm  — Cora-like (2708 nodes / 10556 edges / 1433 feats)
  minibatch_lg   — Reddit-like; REAL fanout sampling via gnn_common
  ogb_products   — products-like full batch (only via input_specs; too big to
                   materialize on CPU, the dry-run uses ShapeDtypeStructs)
  molecule       — batched small graphs (30 nodes / 64 edges × batch)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models import gnn_common


@dataclasses.dataclass
class SynthGraph:
    src: np.ndarray
    dst: np.ndarray
    positions: np.ndarray
    node_feat: np.ndarray | None
    node_type: np.ndarray
    labels: np.ndarray
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return len(self.src)


def synth_graph(
    n_nodes: int, n_edges: int, d_feat: int = 0, n_classes: int = 8,
    n_types: int = 16, seed: int = 0,
) -> SynthGraph:
    """Random geometric-ish graph: nodes get 3D positions (the equiformer
    backbone needs them; non-geometric datasets get synthetic coordinates,
    see DESIGN.md), edges biased to nearby nodes."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    # half locality-biased edges, half uniform (keeps degree dist interesting)
    half = n_edges // 2
    src_a = rng.integers(0, n_nodes, size=half)
    dst_a = (src_a + rng.integers(1, max(2, n_nodes // 100), size=half)) % n_nodes
    src_b = rng.integers(0, n_nodes, size=n_edges - half)
    dst_b = rng.integers(0, n_nodes, size=n_edges - half)
    src = np.concatenate([src_a, src_b])
    dst = np.concatenate([dst_a, dst_b])
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32) if d_feat else None
    return SynthGraph(
        src=src.astype(np.int64), dst=dst.astype(np.int64),
        positions=pos,
        node_feat=feat,
        node_type=rng.integers(0, n_types, size=n_nodes).astype(np.int32),
        labels=rng.integers(0, n_classes, size=n_nodes).astype(np.int32),
        n_nodes=n_nodes,
    )


def full_batch(g: SynthGraph) -> dict[str, np.ndarray]:
    b = {
        "positions": g.positions,
        "src": g.src.astype(np.int32),
        "dst": g.dst.astype(np.int32),
        "edge_mask": np.ones(g.n_edges, np.float32),
        "node_mask": np.ones(g.n_nodes, np.float32),
        "node_type": g.node_type,
        "labels": g.labels,
    }
    if g.node_feat is not None:
        b["node_feat"] = g.node_feat
    return b


class FanoutLoader:
    """minibatch_lg: real neighbor sampling to static-padded subgraph batches."""

    def __init__(self, g: SynthGraph, batch_nodes: int, fanouts: list[int],
                 max_nodes: int, max_edges: int, seed: int = 0):
        self.g = g
        self.csr = gnn_common.CSRGraph.from_edge_index(g.src, g.dst, g.n_nodes)
        self.batch_nodes = batch_nodes
        self.fanouts = fanouts
        self.max_nodes = max_nodes
        self.max_edges = max_edges
        self.rng = np.random.default_rng(seed)

    def next_batch(self) -> dict[str, np.ndarray]:
        seeds = self.rng.choice(self.g.n_nodes, size=self.batch_nodes, replace=False)
        nodes, src, dst = gnn_common.sample_fanout(
            self.csr, seeds, self.fanouts, self.rng
        )
        nodes = nodes[: self.max_nodes]
        keep = (src < self.max_nodes) & (dst < self.max_nodes)
        src, dst = src[keep][: self.max_edges], dst[keep][: self.max_edges]
        pad = gnn_common.pad_graph_batch(
            src, dst, len(nodes), self.max_nodes, self.max_edges
        )
        sel = np.full(self.max_nodes, nodes[-1] if len(nodes) else 0, np.int64)
        sel[: len(nodes)] = nodes
        batch = {
            "positions": self.g.positions[sel],
            "node_type": self.g.node_type[sel],
            "labels": np.where(
                pad["node_mask"] > 0, self.g.labels[sel], -1
            ).astype(np.int32),
            **pad,
        }
        if self.g.node_feat is not None:
            batch["node_feat"] = self.g.node_feat[sel]
        return batch


def molecule_batch(
    batch: int, n_nodes: int, n_edges: int, seed: int = 0
) -> dict[str, np.ndarray]:
    """Batched small graphs flattened into one disjoint union (graph_id map)."""
    rng = np.random.default_rng(seed)
    n_tot, e_tot = batch * n_nodes, batch * n_edges
    src = np.concatenate([
        rng.integers(0, n_nodes, size=n_edges) + i * n_nodes for i in range(batch)
    ])
    dst = np.concatenate([
        rng.integers(0, n_nodes, size=n_edges) + i * n_nodes for i in range(batch)
    ])
    return {
        "positions": rng.normal(size=(n_tot, 3)).astype(np.float32),
        "node_type": rng.integers(0, 16, size=n_tot).astype(np.int32),
        "src": src.astype(np.int32),
        "dst": dst.astype(np.int32),
        "edge_mask": np.ones(e_tot, np.float32),
        "node_mask": np.ones(n_tot, np.float32),
        "graph_id": np.repeat(np.arange(batch, dtype=np.int32), n_nodes),
        "targets": rng.normal(size=batch).astype(np.float32),
    }

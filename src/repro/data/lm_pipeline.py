"""LM token pipeline: synthetic corpus + IDL-BF n-gram dedup + batching.

This is where the paper's technique integrates with the LM archs (DESIGN.md
§4.2): training-data n-gram dedup is a membership-testing problem over a
sliding window of token n-grams — structurally identical to gene kmer search.
Sequential n-grams of one document are near-duplicates of each other, so an
IDL-hashed Bloom filter gives the same probe-locality win as on genomic
reads; an RH-hashed filter is the baseline.

Deterministic resume: the pipeline's cursor (document index, rng state) is
part of its state dict and is saved/restored by the checkpoint layer, so a
restarted job replays the exact token order (DESIGN.md §6 fault tolerance).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing


@dataclasses.dataclass
class LMPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_docs: int = 4096
    doc_len: int = 512
    dedup: bool = True
    dedup_ngram: int = 8
    dedup_bf_bits: int = 1 << 22
    dedup_eta: int = 2
    dedup_scheme: str = "idl"   # "idl" | "rh" — technique integration point
    dedup_L: int = 1 << 12


class _NgramBF:
    """Host-side Bloom filter over token n-grams (numpy; streaming scale).

    IDL scheme: exactly the paper's construction with t=1 sub-tokens —
    anchor = RH(MinHash over the n-token window) (consecutive windows share
    their min with prob (n-1)/(n+1), like overlapping kmers share sub-kmers),
    local = RH(full n-gram) in [L]. RH scheme: plain per-n-gram hash.
    """

    def __init__(self, cfg: LMPipelineConfig):
        self.cfg = cfg
        self.bits = np.zeros(cfg.dedup_bf_bits // 8, dtype=np.uint8)
        self.probes = 0
        self.byte_trace: list[np.ndarray] = []

    def _locations_idl(self, ngrams: np.ndarray, anchors: np.ndarray,
                       j: int, m_part: int) -> np.ndarray:
        cfg = self.cfg
        anchor = hashing.np_hash_to_range(
            anchors, 0xA17C + 31 * j, max(m_part // cfg.dedup_L, 1)
        ).astype(np.int64) * cfg.dedup_L
        local = hashing.np_hash_to_range(
            ngrams, 0x10CA + 31 * j, cfg.dedup_L
        ).astype(np.int64)
        return anchor + local + j * m_part

    def _locations_rh(self, ngrams: np.ndarray, anchors: np.ndarray,
                      j: int, m_part: int) -> np.ndarray:
        del anchors
        return hashing.np_hash_to_range(
            ngrams, 0x5EED + 31 * j, m_part
        ).astype(np.int64) + j * m_part

    def _locations(self, ngrams: np.ndarray, anchors: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        m_part = cfg.dedup_bf_bits // cfg.dedup_eta
        loc_fn = {"idl": self._locations_idl}.get(
            cfg.dedup_scheme, self._locations_rh)
        return np.stack(
            [loc_fn(ngrams, anchors, j, m_part) for j in range(cfg.dedup_eta)],
            axis=0,
        )  # (eta, n)

    def check_and_insert(self, tokens: np.ndarray) -> float:
        """Returns the fraction of the doc's n-grams already seen."""
        n = self.cfg.dedup_ngram
        if len(tokens) < n:
            return 0.0
        # rolling pack: polynomial hash of each n-gram window; anchor from a
        # rolling MinHash of per-token hashes over the same window
        base = np.uint64(1000003)
        t = tokens.astype(np.uint64)
        n_out = len(t) - n + 1
        ngrams = np.zeros(n_out, dtype=np.uint64)
        with np.errstate(over="ignore"):
            for j in range(n):
                ngrams = ngrams * base + t[j : j + n_out]
        htok = hashing.np_hash64(t, 0x0D0F)
        windows = np.lib.stride_tricks.sliding_window_view(htok, n)
        minh = windows.min(axis=1)                   # (n_out,) rolling MinHash
        locs = self._locations(ngrams, minh)
        self.probes += locs.size
        self.byte_trace.append(locs.reshape(-1) // 8)
        byte_idx = (locs // 8).astype(np.int64)
        bit = (locs % 8).astype(np.uint8)
        present = ((self.bits[byte_idx] >> bit) & 1).all(axis=0)
        np.bitwise_or.at(self.bits, byte_idx.reshape(-1), (np.uint8(1) << bit).reshape(-1))
        return float(present.mean())


class LMPipeline:
    """Deterministic synthetic-document stream with n-gram dedup filtering."""

    def __init__(self, cfg: LMPipelineConfig):
        self.cfg = cfg
        self.cursor = 0
        self.bf = _NgramBF(cfg) if cfg.dedup else None
        self.dropped = 0
        self._buf: list[np.ndarray] = []

    # -- deterministic doc source ------------------------------------------
    def _doc(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(self.cfg.seed * 1_000_003 + i)
        doc = rng.integers(1, self.cfg.vocab, size=self.cfg.doc_len, dtype=np.int32)
        # plant exact duplicates: every 7th doc repeats doc i-7
        if i % 7 == 0 and i >= 7:
            return self._doc(i - 7)
        return doc

    def state_dict(self) -> dict:
        return {"cursor": self.cursor, "dropped": self.dropped}

    def load_state_dict(self, state: dict) -> None:
        # replay the BF to the cursor for exact-resume dedup decisions
        self.cursor = 0
        self.dropped = 0
        self.bf = _NgramBF(self.cfg) if self.cfg.dedup else None
        self._buf = []
        target = int(state["cursor"])
        while self.cursor < target:
            self._pull_doc()
        self._buf = []  # batches already consumed

    def _pull_doc(self) -> None:
        doc = self._doc(self.cursor)
        self.cursor += 1
        if self.bf is not None:
            dup_frac = self.bf.check_and_insert(doc)
            if dup_frac > 0.5:
                self.dropped += 1
                return
        self._buf.append(doc)

    def next_batch(self) -> dict[str, np.ndarray]:
        """(tokens, labels) of shape (global_batch, seq_len)."""
        cfg = self.cfg
        need = cfg.global_batch * (cfg.seq_len + 1)
        stream: list[np.ndarray] = []
        total = 0
        while total < need:
            while not self._buf:
                self._pull_doc()
            d = self._buf.pop(0)
            stream.append(d)
            total += len(d)
        flat = np.concatenate(stream)[:need].reshape(cfg.global_batch, cfg.seq_len + 1)
        return {"tokens": flat[:, :-1].astype(np.int32),
                "labels": flat[:, 1:].astype(np.int32)}

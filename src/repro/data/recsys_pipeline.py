"""RecSys click/session synthesis for the four assigned recsys archs.

Sessions have *temporal locality* in their item ids (users browse related
items whose raw ids cluster) — exactly the correlation the IDL-hashed
embedding-row assignment exploits (models/recsys.hash_rows scheme="idl").
The generator plants that structure so the locality benchmarks measure
something real rather than iid ids.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RecsysSynthConfig:
    n_items: int = 1 << 20
    n_users: int = 1 << 18
    session_len: int = 50
    locality: float = 0.8      # prob. next item is near the previous one
    neighborhood: int = 256    # id radius of "related" items
    seed: int = 0


class SessionGenerator:
    def __init__(self, cfg: RecsysSynthConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def sessions(self, batch: int) -> np.ndarray:
        """(batch, session_len) int32 item ids with planted locality."""
        cfg = self.cfg
        out = np.empty((batch, cfg.session_len), dtype=np.int64)
        cur = self.rng.integers(0, cfg.n_items, size=batch)
        for s in range(cfg.session_len):
            jump = self.rng.random(batch) >= cfg.locality
            near = (
                cur + self.rng.integers(-cfg.neighborhood, cfg.neighborhood + 1, size=batch)
            ) % cfg.n_items
            far = self.rng.integers(0, cfg.n_items, size=batch)
            cur = np.where(jump, far, near)
            out[:, s] = cur
        return out.astype(np.int32)

    # -- per-arch batch builders -------------------------------------------
    def sasrec_batch(self, batch: int) -> dict[str, np.ndarray]:
        seq = self.sessions(batch)
        pos = np.roll(seq, -1, axis=1)
        pos[:, -1] = self.rng.integers(0, self.cfg.n_items, size=batch)
        neg = self.rng.integers(0, self.cfg.n_items, size=seq.shape).astype(np.int32)
        return {"seq": seq, "pos": pos.astype(np.int32), "neg": neg}

    def mind_batch(self, batch: int, n_negs: int = 10) -> dict[str, np.ndarray]:
        seq = self.sessions(batch)
        return {
            "seq": seq,
            "mask": np.ones(seq.shape, np.float32),
            "pos": self.rng.integers(0, self.cfg.n_items, size=batch).astype(np.int32),
            "negs": self.rng.integers(
                0, self.cfg.n_items, size=(batch, n_negs)
            ).astype(np.int32),
        }

    def fm_batch(self, batch: int, n_sparse: int = 39,
                 vocab_per_field: int = 1 << 20) -> dict[str, np.ndarray]:
        feats = self.rng.integers(0, vocab_per_field, size=(batch, n_sparse))
        # label correlates with a planted linear rule so training can learn
        signal = (feats[:, 0] % 7 == 0) | (feats[:, 3] % 11 == 0)
        noise = self.rng.random(batch) < 0.1
        return {
            "feats": feats.astype(np.int32),
            "labels": (signal ^ noise).astype(np.int32),
        }

    def twotower_batch(self, batch: int, n_user_feats: int = 8,
                       n_item_feats: int = 4) -> dict[str, np.ndarray]:
        return {
            "user_feats": self.rng.integers(
                0, self.cfg.n_users, size=(batch, n_user_feats)
            ).astype(np.int32),
            "item_feats": self.rng.integers(
                0, self.cfg.n_items, size=(batch, n_item_feats)
            ).astype(np.int32),
        }

    def retrieval_batch(self, n_candidates: int,
                        n_user_feats: int = 8, n_item_feats: int = 4) -> dict:
        return {
            "user_feats": self.rng.integers(
                0, self.cfg.n_users, size=(1, n_user_feats)
            ).astype(np.int32),
            "cand_feats": self.rng.integers(
                0, self.cfg.n_items, size=(n_candidates, n_item_feats)
            ).astype(np.int32),
        }
